//! Cross-module integration tests: whole-pod simulations exercising the
//! config system, collective generators, network, translation hierarchy
//! and stats together. Heavier invariants than the per-module unit tests.

use ratsim::collective::{generators, mscclang, Schedule};
use ratsim::config::presets::{paper_baseline, paper_ideal, quick_test};
use ratsim::config::{CollectiveKind, PodConfig, RequestSizing};
use ratsim::pod::SessionBuilder;
use ratsim::stats::RunStats;
use ratsim::util::units::{GIB, MIB};

fn tiny(gpus: u32, size: u64) -> PodConfig {
    let mut c = quick_test(gpus, size);
    c.workload.request_sizing = RequestSizing::Auto { target_total_requests: 8_000 };
    c
}

/// Session-backed run of the config-declared collective.
fn run(cfg: &PodConfig) -> anyhow::Result<RunStats> {
    Ok(SessionBuilder::new(cfg).build()?.run_to_completion())
}

/// Session-backed run of an explicit schedule.
fn run_schedule(cfg: &PodConfig, schedule: Schedule) -> anyhow::Result<RunStats> {
    Ok(SessionBuilder::new(cfg).schedule(schedule).build()?.run_to_completion())
}

#[test]
fn overhead_monotonically_amortizes_with_size() {
    // §4.1: the RAT overhead ratio decays as collective size grows.
    let mut ratios = Vec::new();
    for size in [MIB, 8 * MIB, 64 * MIB] {
        let b = run(&tiny(8, size)).unwrap();
        let mut ic = tiny(8, size);
        ic.trans.enabled = false;
        let i = run(&ic).unwrap();
        ratios.push(b.completion as f64 / i.completion as f64);
    }
    assert!(ratios[0] > ratios[1] && ratios[1] >= ratios[2], "ratios not decaying: {ratios:?}");
    // 8-GPU pods see a milder penalty than 16-GPU ones (shorter
    // serialization window per pair hides less of the walk at 16).
    assert!(ratios[0] > 1.05, "1MiB overhead too small: {}", ratios[0]);
}

#[test]
fn mean_rat_latency_decays_with_size() {
    // §4.2 / Fig 5.
    let small = run(&tiny(16, MIB)).unwrap();
    let large = run(&tiny(16, 64 * MIB)).unwrap();
    assert!(
        small.mean_rat_ns() > 4.0 * large.mean_rat_ns(),
        "cold-dominated small collectives must have much higher per-request RAT: {} vs {}",
        small.mean_rat_ns(),
        large.mean_rat_ns()
    );
}

#[test]
fn translation_working_set_tracks_gpu_count() {
    // §4.4: the destination's *translated* working set is exactly the
    // inter-node sources' regions — intra-node traffic is SPA-addressed
    // and never walks (§2.3). With 4 GPUs/node, gpus-4 sources are
    // inter-node, each contributing chunk/page pages.
    for gpus in [8u32, 16] {
        let s = run(&tiny(gpus, 64 * MIB)).unwrap();
        let chunk_pages = (64 * MIB / gpus as u64 / (2 * MIB)) as usize;
        let expected = chunk_pages * (gpus as usize - 4);
        assert_eq!(
            s.max_touched_pages, expected,
            "{gpus} GPUs: touched {} != inter-node working set {expected}",
            s.max_touched_pages
        );
    }
}

#[test]
fn l2_sizing_insight_fig11() {
    // §4.5: shrinking L2 below the working set hurts; growing it beyond
    // the per-GPU stream count doesn't help.
    let run_with_l2 = |entries: u32| {
        let mut c = tiny(16, 16 * MIB);
        c.trans.l2.entries = entries;
        run(&c).unwrap().completion
    };
    let small = run_with_l2(16);
    let fits = run_with_l2(32);
    let huge = run_with_l2(32768);
    let rel = |a: u64, b: u64| (a as f64 - b as f64).abs() / b as f64;
    assert!(
        rel(fits, huge) < 0.02,
        "32-entry L2 should match 32768-entry: {fits} vs {huge}"
    );
    assert!(small >= fits, "undersized L2 cannot be faster");
}

#[test]
fn custom_schedule_roundtrips_through_json_and_runs() {
    // MSCCLang-style flow: synthesize → export JSON → import → simulate.
    let sched = generators::alltoall_allpairs(8, MIB).unwrap();
    let dir = std::env::temp_dir().join("ratsim-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("a2a.json");
    mscclang::save(&sched, &path).unwrap();
    let loaded = mscclang::load(&path).unwrap();
    let stats = run_schedule(&tiny(8, MIB), loaded).unwrap();
    assert!(stats.completion > 0);
    // Identical to generating directly.
    let direct = run_schedule(&tiny(8, MIB), sched).unwrap();
    assert_eq!(stats.completion, direct.completion);
    std::fs::remove_file(path).ok();
}

#[test]
fn collectives_have_expected_relative_cost() {
    let mut cfg = tiny(8, 4 * MIB);
    cfg.workload.collective = CollectiveKind::AllToAll;
    let a2a = run(&cfg).unwrap();
    cfg.workload.collective = CollectiveKind::AllGather;
    let ag = run(&cfg).unwrap();
    cfg.workload.collective = CollectiveKind::AllReduce;
    let ar = run(&cfg).unwrap();
    // Direct AG and A2A move the same volume concurrently — within 25%.
    let rel = (a2a.completion as f64 - ag.completion as f64).abs() / ag.completion as f64;
    assert!(rel < 0.25, "A2A vs AG mismatch: {} vs {}", a2a.completion, ag.completion);
    // Ring is serialized into 2(N-1) dependent phases: much slower.
    assert!(ar.completion > 3 * ag.completion);
}

#[test]
fn config_json_roundtrip_preserves_simulation() {
    let cfg = tiny(8, MIB);
    let dir = std::env::temp_dir().join("ratsim-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    cfg.save(&path).unwrap();
    let loaded = PodConfig::load(&path).unwrap();
    assert_eq!(
        run(&cfg).unwrap().completion,
        run(&loaded).unwrap().completion
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn seeds_change_page_tables_not_results_shape() {
    let mut a = tiny(8, MIB);
    a.seed = 1;
    let mut b = tiny(8, MIB);
    b.seed = 2;
    let ra = run(&a).unwrap();
    let rb = run(&b).unwrap();
    // The schedule is deterministic, so timing is identical; only the SPA
    // scatter differs (not visible in timing for this model).
    assert_eq!(ra.requests, rb.requests);
    assert_eq!(ra.completion, rb.completion);
}

#[test]
fn intra_node_only_pod_has_zero_rat() {
    // 4 GPUs on one node: all SPA traffic.
    let s = run(&tiny(4, MIB)).unwrap();
    assert_eq!(s.internode_requests, 0);
    assert_eq!(s.breakdown.translation, 0);
    assert_eq!(s.classes.intra_node, s.requests);
}

#[test]
fn pretranslate_capped_pages_partial_benefit() {
    // §6.1 with a budget: warming only the first page per pair helps less
    // than warming everything but more than nothing.
    let size = 32 * MIB;
    let cold = run(&tiny(8, size)).unwrap();
    let mut one = tiny(8, size);
    one.trans.pretranslate.enabled = true;
    one.trans.pretranslate.pages_per_pair = 1;
    let one_page = run(&one).unwrap();
    let mut all = tiny(8, size);
    all.trans.pretranslate.enabled = true;
    all.trans.pretranslate.pages_per_pair = 0;
    let all_pages = run(&all).unwrap();
    assert!(one_page.completion <= cold.completion);
    assert!(all_pages.completion <= one_page.completion);
    assert!(all_pages.pretranslated_pages > one_page.pretranslated_pages);
}

#[test]
fn fixed_request_sizing_respected() {
    let mut c = tiny(8, MIB);
    c.workload.request_sizing = RequestSizing::Fixed(1024);
    assert_eq!(c.request_bytes(), 1024);
    let s = run(&c).unwrap();
    // 8 GPUs × 7 dsts × (1MiB/8 / 1KiB) requests
    assert_eq!(s.requests, 8 * 7 * (MIB / 8) / 1024);
}

#[test]
fn four_gib_collective_is_simulable() {
    // The paper's largest size: auto-coarsening keeps this tractable.
    let mut c = quick_test(8, 4 * GIB);
    c.workload.request_sizing = RequestSizing::Auto { target_total_requests: 50_000 };
    let s = run(&c).unwrap();
    assert!(s.completion > 0);
    // Auto-coarsening caps at 32 KiB requests (>= 64 per 2 MiB page), so
    // 28 GiB of traffic becomes ~917k requests — tractable, not millions.
    assert!(s.requests <= 1_000_000);
    // Large collectives amortize: RAT is a tiny fraction (§4.1).
    assert!(s.rat_fraction() < 0.02, "rat fraction {}", s.rat_fraction());
}

#[test]
fn second_iteration_runs_warm() {
    // §4: warm-up dominates. A second back-to-back All-to-All (TLBs warm)
    // must cost nearly the ideal iteration, unlike the cold first.
    let cfg = tiny(16, MIB);
    let sched = generators::alltoall_allpairs(16, MIB).unwrap();
    let once = run_schedule(&cfg, sched.repeat(1)).unwrap();
    let twice = run_schedule(&cfg, sched.repeat(2)).unwrap();
    let mut icfg = cfg.clone();
    icfg.trans.enabled = false;
    let ideal = run(&icfg).unwrap();
    let cold = once.completion as f64;
    let warm = twice.completion as f64 - cold;
    let ideal_t = ideal.completion as f64;
    assert!(cold / ideal_t > 1.15, "cold iteration should carry the RAT penalty");
    assert!(
        warm / ideal_t < 1.10,
        "warm iteration should be near-ideal: warm={warm} ideal={ideal_t}"
    );
    // No new walks in iteration 2: walk count identical to a single run.
    assert_eq!(twice.walks_started, once.walks_started);
}

#[test]
fn paper_presets_run_at_full_fidelity_1mib() {
    // Full Table-1 fidelity for the headline cell (256 B requests).
    let b = run(&paper_baseline(16, MIB)).unwrap();
    let i = run(&paper_ideal(16, MIB)).unwrap();
    let ratio = b.completion as f64 / i.completion as f64;
    assert!((1.15..=1.6).contains(&ratio), "headline overhead {ratio:.3} out of band");
    // Fig 6: ~30% of RTT in translation at 1 MiB.
    assert!((0.15..=0.45).contains(&b.rat_fraction()), "rat fraction {}", b.rat_fraction());
    // Fig 7: L1-MSHR hits dominate.
    assert!(b.classes.fig7_fractions()[1] > 0.80);
}
