"""AOT lowering: JAX/Pallas → HLO *text* artifacts for the Rust runtime.

Python runs once, at build time (`make artifacts`); the Rust binary is
self-contained afterwards. Interchange is HLO text, NOT serialized
HloModuleProto: jax ≥ 0.5 emits protos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation (return_tuple=True) → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(fn, example_args, name, out_dir):
    """Lower `fn(*example_args)` and write `<name>.hlo.txt`; returns the
    manifest entry."""
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in example_args]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = fn(*example_args)
    entry = {
        "name": name,
        "file": fname,
        "input_shapes": [list(a.shape) for a in example_args],
        "input_dtypes": [str(a.dtype) for a in example_args],
        "num_outputs": len(outs),
    }
    print(f"  {name}: {len(text)} chars, inputs {entry['input_shapes']}, "
          f"{entry['num_outputs']} outputs")
    return entry


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    # L2 model: the MoE FFN block (contains the L1 moe_ffn Pallas kernel).
    entries.append(
        lower_artifact(model.moe_layer_tuple, model.example_inputs(), "moe_layer", out_dir)
    )

    # §6.1 pre-translation schedule generator (L1 page_schedule kernel).
    n_streams = 15  # 16-GPU pod: streams from one source to 15 destinations
    base = jnp.arange(n_streams, dtype=jnp.float32) * (1 << 20)
    length = jnp.full((n_streams,), float(1 << 20), jnp.float32)
    entries.append(
        lower_artifact(model.page_schedule_graph, (base, length), "page_schedule", out_dir)
    )

    manifest = {"artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Golden vectors: the Rust runtime test replays these through PJRT and
    # asserts allclose — the cross-language numerical contract.
    inputs = model.example_inputs()
    out, load = model.moe_layer_tuple(*inputs)
    golden = {
        "moe_layer": {
            "inputs": [[float(v) for v in a.reshape(-1)] for a in inputs],
            "outputs": [
                [float(v) for v in out.reshape(-1)],
                [float(v) for v in load.reshape(-1)],
            ],
        }
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"wrote {out_dir}/manifest.json ({len(entries)} artifacts) + golden.json")
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
