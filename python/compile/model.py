"""L2 JAX model: one MoE transformer FFN block, per-GPU shard.

This is the compute phase between the paper's two All-to-Alls (§2.5):

  dispatch A2A  →  [this model: gate → dispatch → expert FFN → combine]
                →  combine A2A

The expert FFN is the L1 Pallas kernel (`kernels.moe_ffn`); gating,
dispatch and combine are plain jnp so the whole shard lowers into one HLO
module that the Rust runtime executes via PJRT. A second exported graph
(`page_schedule_graph`) is the §6.1 fused pre-translation address
generator.
"""

import jax
import jax.numpy as jnp

from .kernels.moe_ffn import moe_ffn
from .kernels.page_schedule import page_schedule

# Default shard geometry for the end-to-end example: small enough that
# `make artifacts` is fast, large enough to exercise every op.
TOKENS = 64
D_MODEL = 32
D_FF = 64
EXPERTS = 4


def moe_layer(tokens, gate_w, w1, w2):
    """One MoE FFN block over this GPU's tokens.

    Args:
      tokens: (T, D) activations.
      gate_w: (D, E) router weights.
      w1:     (E, D, F) expert up-projections.
      w2:     (E, F, D) expert down-projections.
    Returns:
      (output (T, D), expert_load (E,)) — expert_load is the routed token
      count per expert, which sizes the dispatch All-to-All chunks.
    """
    logits = tokens @ gate_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.argmax(probs, axis=-1)  # (T,)
    onehot = jax.nn.one_hot(top, probs.shape[-1], dtype=tokens.dtype)  # (T, E)
    gate = jnp.sum(probs * onehot, axis=-1, keepdims=True)  # (T, 1) top-1 prob

    # Dispatch: every expert sees all tokens, masked to its assignment
    # (capacity = T — the dense formulation; the A2A exchanges exactly
    # these masked slices).
    dispatched = jnp.einsum("te,td->etd", onehot, tokens)  # (E, T, D)

    expert_out = moe_ffn(dispatched, w1, w2)  # (E, T, D) — L1 Pallas kernel

    # Combine: gather each token's expert output, scaled by its gate.
    combined = jnp.einsum("te,etd->td", onehot, expert_out) * gate
    expert_load = jnp.sum(onehot, axis=0)  # (E,)
    return combined, expert_load


def moe_layer_tuple(tokens, gate_w, w1, w2):
    """Tuple-returning wrapper for AOT lowering."""
    out, load = moe_layer(tokens, gate_w, w1, w2)
    return (out, load)


def page_schedule_graph(base, length):
    """§6.1 pre-translation schedule for the upcoming All-to-All."""
    return (page_schedule(base, length, pages_per_stream=8),)


def example_inputs(seed: int = 0):
    """Deterministic example inputs matching the exported shapes."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    tokens = jax.random.normal(k1, (TOKENS, D_MODEL), jnp.float32)
    gate_w = jax.random.normal(k2, (D_MODEL, EXPERTS), jnp.float32) * 0.3
    w1 = jax.random.normal(k3, (EXPERTS, D_MODEL, D_FF), jnp.float32) * 0.1
    w2 = jax.random.normal(k4, (EXPERTS, D_FF, D_MODEL), jnp.float32) * 0.1
    return tokens, gate_w, w1, w2
