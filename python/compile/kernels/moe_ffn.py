"""L1 Pallas kernel: grouped Mixture-of-Experts FFN.

The paper motivates All-to-All with MoE layers (§2.5): tokens are
dispatched to experts, each expert runs an FFN, outputs are combined. This
kernel is the expert-compute hot-spot between the two All-to-Alls — the
grouped matmul ``relu(x[e] @ w1[e]) @ w2[e]`` for every expert ``e``.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): a CUDA MoE kernel
tiles with threadblocks + shared memory; on TPU we express the same
schedule with a Pallas grid over ``(expert, token-tile)`` and BlockSpecs
that stage one token tile plus both weight matrices of the current expert
in VMEM, feeding the MXU with (tile × d_model) @ (d_model × d_ff) blocks.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads (see /opt/xla-example/README.md).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One grid step: token-tile ``x`` through expert FFN ``w1, w2``.

    x_ref:  (tile, d_model)   VMEM
    w1_ref: (d_model, d_ff)   VMEM
    w2_ref: (d_ff, d_model)   VMEM
    o_ref:  (tile, d_model)   VMEM
    """
    x = x_ref[...]
    h = jnp.maximum(x @ w1_ref[...], 0.0)  # MXU matmul + VPU relu
    o_ref[...] = h @ w2_ref[...]


def pick_tile(tokens: int, preferred: int = 128) -> int:
    """Largest divisor of ``tokens`` that is ≤ preferred (MXU-friendly
    tiles are multiples of 8×128 on real TPUs; tests use small shapes)."""
    tile = min(preferred, tokens)
    while tokens % tile != 0:
        tile -= 1
    return max(tile, 1)


@partial(jax.jit, static_argnames=("tile",))
def moe_ffn(x, w1, w2, tile: int | None = None):
    """Grouped expert FFN.

    Args:
      x:  (experts, tokens, d_model) tokens already dispatched per expert.
      w1: (experts, d_model, d_ff)
      w2: (experts, d_ff, d_model)
    Returns:
      (experts, tokens, d_model)
    """
    e, t, d = x.shape
    _, _, f = w1.shape
    if tile is None:
        tile = pick_tile(t)
    grid = (e, t // tile)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            # Stream one token tile of one expert per step: HBM→VMEM.
            pl.BlockSpec((None, tile, d), lambda e_, i: (e_, i, 0)),
            # Expert weights resident for the whole expert's tiles.
            pl.BlockSpec((None, d, f), lambda e_, i: (e_, 0, 0)),
            pl.BlockSpec((None, f, d), lambda e_, i: (e_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, tile, d), lambda e_, i: (e_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, t, d), x.dtype),
        interpret=True,
    )(x, w1, w2)


def vmem_bytes(tile: int, d_model: int, d_ff: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint of one grid step (DESIGN/EXPERIMENTS §Perf):
    x tile + w1 + w2 + output tile."""
    return dtype_bytes * (tile * d_model + d_model * d_ff + d_ff * d_model + tile * d_model)


def mxu_flops(tile: int, d_model: int, d_ff: int) -> int:
    """MAC-flops per grid step (2 matmuls)."""
    return 2 * tile * d_model * d_ff * 2
