"""L1 Pallas kernel: pre-translation page-schedule generator (§6.1).

The paper's first optimization proposal is the *fused pre-translation
kernel*: while the compute kernel (the expert FFN) runs, it also computes
the NPA pages the upcoming All-to-All will touch, so translation requests
can be issued ahead of the communication and the Link TLBs are warm by the
time remote stores arrive.

This kernel is that address generator: given each destination stream's
base offset and length, it emits the page-id sequence the stream will
touch (a strided integer computation — pure VPU work, no MXU). The Rust
coordinator feeds the result to the pod's pre-translation warmup engine
(``trans.pretranslate``) in the end-to-end MoE example.

Everything is f32 on the wire because the Rust PJRT path moves f32
buffers; page ids are exact in f32 up to 2^24 (16.7M pages = 32 TiB of
2 MiB pages per GPU — far beyond any pod's window).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _schedule_kernel(base_ref, len_ref, o_ref, *, pages_per_stream, page_bytes):
    """One stream per grid step.

    base_ref: (1,)  f32 — byte offset of the stream in the dst window
    len_ref:  (1,)  f32 — stream length in bytes
    o_ref:    (1, pages_per_stream) f32 — page ids; -1 past the stream end
    """
    base = base_ref[0]
    length = len_ref[0]
    k = jnp.arange(pages_per_stream, dtype=jnp.float32)
    first_page = jnp.floor(base / page_bytes)
    page = first_page + k
    # Pages past the stream's last byte are masked to -1. The condition is
    # `page*P < base+length` rather than `page <= floor((base+length-1)/P)`:
    # `base+length` is exact in f32 for byte counts < 2^24 while the `-1`
    # form rounds at large offsets.
    o_ref[0, :] = jnp.where(page * page_bytes < base + length, page, -1.0)


@partial(jax.jit, static_argnames=("pages_per_stream", "page_bytes"))
def page_schedule(base, length, pages_per_stream: int = 8, page_bytes: int = 2 * 1024 * 1024):
    """Page ids each stream will touch.

    Args:
      base:   (streams,) f32 byte offsets into the destination window.
      length: (streams,) f32 stream lengths in bytes.
    Returns:
      (streams, pages_per_stream) f32 page ids, -1 where masked.
    """
    (n,) = base.shape
    return pl.pallas_call(
        partial(
            _schedule_kernel,
            pages_per_stream=pages_per_stream,
            page_bytes=float(page_bytes),
        ),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, pages_per_stream), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, pages_per_stream), jnp.float32),
        interpret=True,
    )(base, length)
