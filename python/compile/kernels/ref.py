"""Pure-jnp oracles for the Pallas kernels (pytest compares against these)."""

import jax.numpy as jnp
import numpy as np


def moe_ffn_ref(x, w1, w2):
    """Grouped expert FFN: relu(x[e] @ w1[e]) @ w2[e], batched over e."""
    h = jnp.maximum(jnp.einsum("etd,edf->etf", x, w1), 0.0)
    return jnp.einsum("etf,efd->etd", h, w2)


def page_schedule_ref(base, length, pages_per_stream=8, page_bytes=2 * 1024 * 1024):
    """Numpy oracle for the pre-translation schedule."""
    base = np.asarray(base, dtype=np.float64)
    length = np.asarray(length, dtype=np.float64)
    n = base.shape[0]
    out = np.full((n, pages_per_stream), -1.0, dtype=np.float64)
    for i in range(n):
        first = np.floor(base[i] / page_bytes)
        last = np.floor((base[i] + length[i] - 1.0) / page_bytes)
        for k in range(pages_per_stream):
            page = first + k
            if page <= last:
                out[i, k] = page
    return out.astype(np.float32)
