"""AOT path: artifacts lower to valid HLO text + manifest."""

import json
import os

import jax
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_build_all(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build_all(out)
    names = [a["name"] for a in manifest["artifacts"]]
    assert names == ["moe_layer", "page_schedule"]

    # Manifest round-trips and files exist with plausible HLO text.
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        text = open(path).read()
        assert "HloModule" in text, f"{a['name']} is not HLO text"
        assert "ENTRY" in text
        assert len(a["input_shapes"]) == len(a["input_dtypes"])
        assert a["num_outputs"] >= 1

    moe = manifest["artifacts"][0]
    assert moe["input_shapes"] == [
        [model.TOKENS, model.D_MODEL],
        [model.D_MODEL, model.EXPERTS],
        [model.EXPERTS, model.D_MODEL, model.D_FF],
        [model.EXPERTS, model.D_FF, model.D_MODEL],
    ]
    assert all(d == "float32" for d in moe["input_dtypes"])


def test_hlo_text_parses_back(tmp_path):
    """Round-trip sanity: the emitted HLO text parses back into an HLO
    module whose entry signature matches the export (the Rust side repeats
    the full compile+execute through PJRT in rust/tests/runtime_e2e.rs)."""
    from jax._src.lib import xla_client as xc

    inputs = model.example_inputs()
    lowered = jax.jit(model.moe_layer_tuple).lower(
        *[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in inputs]
    )
    text = aot.to_hlo_text(lowered)

    mod = xc._xla.hlo_module_from_text(text)
    rendered = mod.to_string()
    # Entry signature carries the four f32 parameters and tuple result.
    assert f"f32[{model.TOKENS},{model.D_MODEL}]" in rendered
    assert f"f32[{model.EXPERTS},{model.D_MODEL},{model.D_FF}]" in rendered
    assert "ENTRY" in rendered


def test_pallas_kernel_survives_lowering():
    """The lowered moe_layer HLO must contain the kernel's compute (dot +
    maximum): interpret-mode pallas lowers to plain HLO ops that the CPU
    PJRT client can run — no Mosaic custom-calls allowed."""
    inputs = model.example_inputs()
    lowered = jax.jit(model.moe_layer_tuple).lower(
        *[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in inputs]
    )
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text or "tpu" not in text.lower()
    assert "dot(" in text or "dot " in text
    assert "maximum" in text
