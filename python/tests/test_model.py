"""L2 model tests: MoE layer semantics and shapes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import moe_ffn_ref

jax.config.update("jax_platform_name", "cpu")


def test_shapes():
    out, load = model.moe_layer(*model.example_inputs())
    assert out.shape == (model.TOKENS, model.D_MODEL)
    assert load.shape == (model.EXPERTS,)


def test_expert_load_conserves_tokens():
    _, load = model.moe_layer(*model.example_inputs())
    assert float(jnp.sum(load)) == model.TOKENS


def test_deterministic():
    a, _ = model.moe_layer(*model.example_inputs(0))
    b, _ = model.moe_layer(*model.example_inputs(0))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c, _ = model.moe_layer(*model.example_inputs(1))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_matches_manual_top1_moe():
    """Independent dense recomputation of the layer (including the Pallas
    kernel replaced by its oracle)."""
    tokens, gate_w, w1, w2 = model.example_inputs(7)
    out, _ = model.moe_layer(tokens, gate_w, w1, w2)

    probs = jax.nn.softmax(tokens @ gate_w, axis=-1)
    top = jnp.argmax(probs, axis=-1)
    onehot = jax.nn.one_hot(top, model.EXPERTS, dtype=jnp.float32)
    dispatched = jnp.einsum("te,td->etd", onehot, tokens)
    expert_out = moe_ffn_ref(dispatched, w1, w2)
    want = jnp.einsum("te,etd->td", onehot, expert_out) * jnp.sum(
        probs * onehot, axis=-1, keepdims=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_single_expert_reduces_to_plain_ffn():
    """With one expert the MoE layer is gate_prob * FFN(tokens), and the
    top-1 probability of a single expert is 1."""
    k = jax.random.PRNGKey(3)
    tokens = jax.random.normal(k, (8, 4), jnp.float32)
    gate_w = jnp.ones((4, 1), jnp.float32)
    w1 = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 8), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 4), jnp.float32)
    out, load = model.moe_layer(tokens, gate_w, w1, w2)
    want = moe_ffn_ref(tokens[None], w1, w2)[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert float(load[0]) == 8.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hypothesis_gate_mass(seed):
    """The routed token counts always sum to T and are non-negative."""
    tokens, gate_w, w1, w2 = model.example_inputs(seed)
    _, load = model.moe_layer(tokens, gate_w, w1, w2)
    load = np.asarray(load)
    assert load.sum() == model.TOKENS
    assert (load >= 0).all()


def test_page_schedule_graph_shape():
    base = jnp.arange(15, dtype=jnp.float32) * (1 << 20)
    length = jnp.full((15,), float(1 << 20), jnp.float32)
    (sched,) = model.page_schedule_graph(base, length)
    assert sched.shape == (15, 8)
    # 1 MiB streams inside a 2 MiB page: exactly one valid page per stream.
    valid = (np.asarray(sched) >= 0).sum(axis=1)
    np.testing.assert_array_equal(valid, np.ones(15))
