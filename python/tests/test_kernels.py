"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp/numpy refs,
with hypothesis sweeping shapes and value ranges."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.moe_ffn import moe_ffn, mxu_flops, pick_tile, vmem_bytes
from compile.kernels.page_schedule import page_schedule
from compile.kernels.ref import moe_ffn_ref, page_schedule_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


class TestMoeFfn:
    def test_matches_ref_default_shape(self):
        x = rand(0, (4, 64, 32))
        w1 = rand(1, (4, 32, 64), 0.1)
        w2 = rand(2, (4, 64, 32), 0.1)
        np.testing.assert_allclose(
            moe_ffn(x, w1, w2), moe_ffn_ref(x, w1, w2), rtol=1e-5, atol=1e-5
        )

    def test_single_expert_single_token(self):
        x = rand(3, (1, 1, 8))
        w1 = rand(4, (1, 8, 16), 0.2)
        w2 = rand(5, (1, 16, 8), 0.2)
        np.testing.assert_allclose(
            moe_ffn(x, w1, w2), moe_ffn_ref(x, w1, w2), rtol=1e-5, atol=1e-5
        )

    def test_relu_actually_applied(self):
        # All-negative hidden: output must be exactly zero.
        x = jnp.ones((1, 4, 4), jnp.float32)
        w1 = -jnp.ones((1, 4, 8), jnp.float32)
        w2 = rand(6, (1, 8, 4))
        out = moe_ffn(x, w1, w2)
        np.testing.assert_array_equal(np.asarray(out), np.zeros_like(out))

    def test_experts_are_independent(self):
        # Changing expert 1's weights must not change expert 0's output.
        x = rand(7, (2, 16, 8))
        w1 = rand(8, (2, 8, 16), 0.1)
        w2 = rand(9, (2, 16, 8), 0.1)
        a = moe_ffn(x, w1, w2)
        w1b = w1.at[1].mul(3.0)
        b = moe_ffn(x, w1b, w2)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        assert not np.allclose(np.asarray(a[1]), np.asarray(b[1]))

    def test_explicit_tile_sizes(self):
        x = rand(10, (2, 24, 8))
        w1 = rand(11, (2, 8, 12), 0.1)
        w2 = rand(12, (2, 12, 8), 0.1)
        want = moe_ffn_ref(x, w1, w2)
        for tile in (1, 2, 3, 4, 6, 8, 12, 24):
            got = moe_ffn(x, w1, w2, tile=tile)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        e=st.integers(1, 4),
        t_mult=st.integers(1, 6),
        d=st.sampled_from([4, 8, 16]),
        f=st.sampled_from([4, 8, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, e, t_mult, d, f, seed):
        t = 4 * t_mult
        x = rand(seed, (e, t, d))
        w1 = rand(seed + 1, (e, d, f), 0.1)
        w2 = rand(seed + 2, (e, f, d), 0.1)
        np.testing.assert_allclose(
            moe_ffn(x, w1, w2), moe_ffn_ref(x, w1, w2), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=20, deadline=None)
    @given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 1000))
    def test_hypothesis_value_range(self, scale, seed):
        x = rand(seed, (2, 8, 8), scale)
        w1 = rand(seed + 1, (2, 8, 8), scale)
        w2 = rand(seed + 2, (2, 8, 8), scale)
        got = np.asarray(moe_ffn(x, w1, w2))
        want = np.asarray(moe_ffn_ref(x, w1, w2))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale**3)

    @settings(max_examples=12, deadline=None)
    @given(
        dtype=st.sampled_from(["float32", "bfloat16", "float16"]),
        seed=st.integers(0, 500),
    )
    def test_hypothesis_dtype_sweep(self, dtype, seed):
        """The kernel must match its oracle in every dtype the MXU path
        accepts (bf16 is the production TPU dtype; tolerances scale with
        the format's epsilon)."""
        dt = jnp.dtype(dtype)
        x = rand(seed, (2, 16, 8)).astype(dt)
        w1 = rand(seed + 1, (2, 8, 16), 0.2).astype(dt)
        w2 = rand(seed + 2, (2, 16, 8), 0.2).astype(dt)
        got = moe_ffn(x, w1, w2)
        assert got.dtype == dt
        want = moe_ffn_ref(x, w1, w2)
        tol = {"float32": 1e-5, "bfloat16": 5e-2, "float16": 5e-3}[dtype]
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=tol,
            atol=tol,
        )

    def test_pick_tile_divides(self):
        for tokens in (1, 7, 64, 100, 128, 384, 1000):
            tile = pick_tile(tokens)
            assert tokens % tile == 0
            assert 1 <= tile <= 128

    def test_perf_model_arithmetic(self):
        # 128-token tile, d=512, f=2048 in f32: footprint must fit VMEM
        # (~16 MiB/core on modern TPUs) — the BlockSpec design point.
        fp = vmem_bytes(128, 512, 2048)
        assert fp == 4 * (128 * 512 + 512 * 2048 + 2048 * 512 + 128 * 512)
        assert fp < 16 * 1024 * 1024
        assert mxu_flops(128, 512, 2048) == 2 * 2 * 128 * 512 * 2048


class TestPageSchedule:
    PAGE = 2 * 1024 * 1024

    def test_matches_ref_simple(self):
        base = jnp.array([0.0, 1.5 * self.PAGE, 10.0 * self.PAGE], jnp.float32)
        length = jnp.array([self.PAGE, self.PAGE, 4 * self.PAGE], jnp.float32)
        got = page_schedule(base, length, pages_per_stream=8, page_bytes=self.PAGE)
        want = page_schedule_ref(base, length, 8, self.PAGE)
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_single_page_stream(self):
        # A 64 KiB stream inside page 3 touches exactly page 3.
        base = jnp.array([3.0 * self.PAGE + 1024], jnp.float32)
        length = jnp.array([65536.0], jnp.float32)
        got = np.asarray(page_schedule(base, length, 4, self.PAGE))
        np.testing.assert_array_equal(got[0], [3.0, -1.0, -1.0, -1.0])

    def test_page_crossing_stream(self):
        # A stream straddling a boundary touches both pages (§4.4's
        # "request offsets exceed page boundaries" spikes).
        base = jnp.array([self.PAGE - 512.0], jnp.float32)
        length = jnp.array([1024.0], jnp.float32)
        got = np.asarray(page_schedule(base, length, 4, self.PAGE))
        np.testing.assert_array_equal(got[0], [0.0, 1.0, -1.0, -1.0])

    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(1, 16),
        page_exp=st.sampled_from([12, 16, 21]),
        k=st.integers(1, 12),
        data=st.data(),
    )
    def test_hypothesis_matches_ref(self, n, page_exp, k, data):
        page = float(1 << page_exp)
        base = np.array(
            data.draw(
                st.lists(
                    st.integers(0, 1 << 22).map(float), min_size=n, max_size=n
                )
            ),
            dtype=np.float32,
        )
        length = np.array(
            data.draw(
                st.lists(
                    st.integers(1, 1 << 22).map(float), min_size=n, max_size=n
                )
            ),
            dtype=np.float32,
        )
        got = np.asarray(page_schedule(jnp.array(base), jnp.array(length), k, int(page)))
        want = page_schedule_ref(base, length, k, page)
        np.testing.assert_array_equal(got, want)

    def test_output_shape(self):
        base = jnp.zeros((5,), jnp.float32)
        length = jnp.ones((5,), jnp.float32)
        assert page_schedule(base, length, 16, self.PAGE).shape == (5, 16)
