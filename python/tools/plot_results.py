"""Render the figure-harness CSVs (results/*.csv) into PNG plots that
mirror the paper's figures.

Usage: python python/tools/plot_results.py [--results results] [--out results/plots]

Purely a post-processing convenience — the simulator itself only emits
CSVs (and terminal previews), so headless runs never depend on matplotlib.
"""

import argparse
import csv
import os
import sys


def read_csv(path):
    with open(path) as f:
        rows = list(csv.DictReader(f))
    return rows


def size_key(s):
    mult = {"KiB": 1 << 10, "MiB": 1 << 20, "GiB": 1 << 30}
    for suffix, m in mult.items():
        if s.endswith(suffix):
            return float(s[: -len(suffix)]) * m
    return float(s.rstrip("B"))


def plot_fig4(results, out, plt):
    rows = read_csv(os.path.join(results, "fig4_overhead.csv"))
    fig, ax = plt.subplots(figsize=(7, 3.5))
    for gpus in sorted({r["gpus"] for r in rows}, key=int):
        pts = sorted(
            ((size_key(r["size"]), float(r["overhead_x"])) for r in rows if r["gpus"] == gpus)
        )
        ax.plot([p[0] for p in pts], [p[1] for p in pts], marker="o", label=f"{gpus} GPUs")
    ax.set_xscale("log", base=2)
    ax.set_xlabel("collective size (bytes)")
    ax.set_ylabel("slowdown vs ideal")
    ax.set_title("Fig 4 — Reverse-translation overhead (normalized to ideal)")
    ax.legend()
    ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig4_overhead.png"), dpi=130)


def plot_fig5(results, out, plt):
    rows = read_csv(os.path.join(results, "fig5_rat_latency.csv"))
    fig, ax = plt.subplots(figsize=(7, 3.5))
    for gpus in sorted({r["gpus"] for r in rows}, key=int):
        pts = sorted(
            ((size_key(r["size"]), float(r["mean_rat_ns"])) for r in rows if r["gpus"] == gpus)
        )
        ax.plot([p[0] for p in pts], [p[1] for p in pts], marker="s", label=f"{gpus} GPUs")
    ax.set_xscale("log", base=2)
    ax.set_yscale("log")
    ax.set_xlabel("collective size (bytes)")
    ax.set_ylabel("mean RAT latency (ns)")
    ax.set_title("Fig 5 — Average reverse-translation latency per request")
    ax.legend()
    ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig5_rat_latency.png"), dpi=130)


def plot_fig6(results, out, plt):
    rows = read_csv(os.path.join(results, "fig6_rtt_breakdown.csv"))
    rows.sort(key=lambda r: size_key(r["size"]))
    comps = ["fabric", "net_fwd", "reverse_translation", "memory", "net_ack"]
    fig, ax = plt.subplots(figsize=(7, 3.5))
    bottom = [0.0] * len(rows)
    xs = [r["size"] for r in rows]
    for comp in comps:
        vals = [float(r[comp]) for r in rows]
        ax.bar(xs, vals, bottom=bottom, label=comp)
        bottom = [b + v for b, v in zip(bottom, vals)]
    ax.set_ylabel("fraction of request RTT")
    ax.set_title("Fig 6 — RTT breakdown per request (16 GPUs)")
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig6_rtt_breakdown.png"), dpi=130)


def plot_fig7(results, out, plt):
    rows = read_csv(os.path.join(results, "fig7_hier_breakdown.csv"))
    rows.sort(key=lambda r: size_key(r["size"]))
    comps = ["l1_hit", "l1_mshr_hit", "l2_hit", "l2_hum", "pwc_hit", "full_walk"]
    fig, ax = plt.subplots(figsize=(7, 3.5))
    bottom = [0.0] * len(rows)
    xs = [r["size"] for r in rows]
    for comp in comps:
        vals = [float(r[comp]) for r in rows]
        ax.bar(xs, vals, bottom=bottom, label=comp)
        bottom = [b + v for b, v in zip(bottom, vals)]
    ax.set_ylabel("fraction of inter-node requests")
    ax.set_title("Fig 7 — Translation-module hit/miss breakdown (16 GPUs)")
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig7_hier_breakdown.png"), dpi=130)


def plot_traces(results, out, plt):
    fig, axes = plt.subplots(1, 2, figsize=(10, 3.5))
    for ax, (name, title) in zip(
        axes,
        [
            ("fig9_trace_1MiB.csv", "Fig 9 — 1 MiB trace"),
            ("fig10_trace_256MiB.csv", "Fig 10 — 256 MiB trace"),
        ],
    ):
        path = os.path.join(results, name)
        if not os.path.exists(path):
            ax.set_title(f"{title} (missing)")
            continue
        rows = read_csv(path)
        xs = [int(r["seq"]) for r in rows]
        ys = [float(r["rat_ns"]) for r in rows]
        ax.plot(xs, ys, ",", markersize=1)
        ax.set_xlabel("request (issue order)")
        ax.set_ylabel("RAT latency (ns)")
        ax.set_title(title)
        ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig9_10_traces.png"), dpi=130)


def plot_fig11(results, out, plt):
    rows = read_csv(os.path.join(results, "fig11_l2_sweep.csv"))
    fig, ax = plt.subplots(figsize=(7, 3.5))
    for size in sorted({r["size"] for r in rows}, key=size_key):
        pts = sorted(
            ((int(r["l2_entries"]), float(r["overhead_x"])) for r in rows if r["size"] == size)
        )
        ax.plot([p[0] for p in pts], [p[1] for p in pts], marker="d", label=size)
    ax.set_xscale("log", base=2)
    ax.set_xlabel("L2 Link-TLB entries")
    ax.set_ylabel("slowdown vs ideal")
    ax.set_title("Fig 11 — L2-TLB size sweep (32 GPUs)")
    ax.legend()
    ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(os.path.join(out, "fig11_l2_sweep.png"), dpi=130)


PLOTTERS = {
    "fig4_overhead.csv": plot_fig4,
    "fig5_rat_latency.csv": plot_fig5,
    "fig6_rtt_breakdown.csv": plot_fig6,
    "fig7_hier_breakdown.csv": plot_fig7,
    "fig9_trace_1MiB.csv": plot_traces,
    "fig11_l2_sweep.csv": plot_fig11,
}


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--results", default="results")
    p.add_argument("--out", default="results/plots")
    args = p.parse_args()

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    os.makedirs(args.out, exist_ok=True)
    made = 0
    for csv_name, fn in PLOTTERS.items():
        if os.path.exists(os.path.join(args.results, csv_name)):
            fn(args.results, args.out, plt)
            made += 1
        else:
            print(f"skip {csv_name} (not found — run `make figures` first)", file=sys.stderr)
    print(f"wrote {made} plots to {args.out}")


if __name__ == "__main__":
    main()
