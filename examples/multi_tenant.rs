//! Multi-tenant serving study: a 64-GPU pod shared by a decode/prefill
//! inference mix, reported per job — the regime where the paper's cold
//! Link-TLB misses actually bite (many small, latency-sensitive
//! collectives hitting the same destination translation hierarchy).
//!
//! Run with: `cargo run --release --example multi_tenant`
//! (`RATSIM_QUICK=1` trims the request budget for CI smoke runs.)

use ratsim::collective::workload::Workload;
use ratsim::config::presets::{inference_mix_spec, paper_baseline};
use ratsim::config::RequestSizing;
use ratsim::pod::SessionBuilder;
use ratsim::util::units::{fmt_bytes, to_us};

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();

    let gpus = 64;
    let spec = inference_mix_spec(3, 1); // 3 decode tenants + 1 prefill
    let mut cfg = paper_baseline(gpus, 64 << 20);
    cfg.name = format!("multi-tenant-{gpus}gpu");
    // Keep the example snappy; drop this override for full fidelity.
    let budget: u64 =
        if std::env::var("RATSIM_QUICK").is_ok() { 30_000 } else { 300_000 };
    cfg.workload.request_sizing = RequestSizing::Auto { target_total_requests: budget };

    let workload = Workload::from_spec(&spec, gpus, cfg.trans.page_bytes)?;
    println!(
        "workload `{}`: {} jobs, {} total fabric bytes",
        workload.name,
        workload.jobs.len(),
        fmt_bytes(workload.total_bytes())
    );

    let stats = SessionBuilder::new(&cfg).workload(workload).build()?.run_to_completion();
    println!("\n{}\n", stats.summary());
    println!(
        "{:<12} {:>10} {:>12} {:>11} {:>11} {:>11}",
        "job", "arrival_us", "latency_us", "p50_ns", "p95_ns", "p99_ns"
    );
    for j in &stats.jobs {
        println!(
            "{:<12} {:>10.1} {:>12.1} {:>11.0} {:>11.0} {:>11.0}",
            j.name,
            to_us(j.arrival),
            to_us(j.latency()),
            j.rtt_p50_ns(),
            j.rtt_p95_ns(),
            j.rtt_p99_ns()
        );
    }
    println!(
        "\ncross-job TLB interference: {} L1 evictions, {} L2 evictions",
        stats.cross_job_l1_evictions, stats.cross_job_l2_evictions
    );

    // The tenancy contrast: the same decode traffic alone vs sharing the
    // pod. Per-job p99 degrades purely from co-located tenants.
    let solo_spec = inference_mix_spec(3, 0);
    let solo = SessionBuilder::new(&cfg)
        .workload(Workload::from_spec(&solo_spec, gpus, cfg.trans.page_bytes)?)
        .build()?
        .run_to_completion();
    let shared_p99 = stats
        .jobs
        .iter()
        .filter(|j| j.name.starts_with("decode"))
        .map(|j| j.rtt_p99_ns())
        .fold(0f64, f64::max);
    let solo_p99 = solo.jobs.iter().map(|j| j.rtt_p99_ns()).fold(0f64, f64::max);
    println!(
        "\ndecode p99 without the prefill tenant: {solo_p99:.0} ns; sharing the pod: {shared_p99:.0} ns"
    );
    Ok(())
}
