//! Quickstart: simulate one All-to-All on a 16-GPU UALink pod through a
//! `SimSession` and print the reverse-translation report — including a
//! tiny custom `Observer` that watches the cold page walks live.
//!
//! Run with: `cargo run --release --example quickstart`
//! (`RATSIM_QUICK=1` trims the request budget for CI smoke runs.)

use ratsim::config::presets::{paper_baseline, paper_ideal};
use ratsim::config::{PodConfig, RequestSizing};
use ratsim::pod::{Observer, SessionBuilder, SessionEvent};
use ratsim::util::units::{fmt_time, Time, MIB};
use std::cell::Cell;
use std::rc::Rc;

/// A third-party probe: count completed demand walks and remember when
/// the first one landed — no engine changes, just an [`Observer`]
/// attached to the session. Results flow out through shared `Rc<Cell>`
/// handles.
struct WalkProbe {
    walks: Rc<Cell<u64>>,
    first_at: Rc<Cell<Option<Time>>>,
}

impl Observer for WalkProbe {
    fn on_event(&mut self, now: Time, ev: &SessionEvent) {
        if let SessionEvent::WalkCompleted { prefetch: false, .. } = ev {
            self.walks.set(self.walks.get() + 1);
            if self.first_at.get().is_none() {
                self.first_at.set(Some(now));
            }
        }
    }
}

fn tune(mut cfg: PodConfig) -> PodConfig {
    if std::env::var("RATSIM_QUICK").is_ok() {
        cfg.workload.request_sizing = RequestSizing::Auto { target_total_requests: 20_000 };
    }
    cfg
}

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();

    // Table-1 baseline: 16 GPUs (4 per node), 1 MiB all-pairs All-to-All.
    let cfg = tune(paper_baseline(16, MIB));
    println!("pod: {} GPUs, {} stations/GPU, {} request bytes", cfg.gpus,
        cfg.link.stations_per_gpu, cfg.request_bytes());

    let walks = Rc::new(Cell::new(0u64));
    let first_at = Rc::new(Cell::new(None));
    let stats = SessionBuilder::new(&cfg)
        .observe(WalkProbe { walks: Rc::clone(&walks), first_at: Rc::clone(&first_at) })
        .build()?
        .run_to_completion();
    println!("\nbaseline:  {}", stats.summary());
    println!(
        "probe:     {} demand walks, first completed at {}",
        walks.get(),
        first_at.get().map(fmt_time).unwrap_or_else(|| "-".into())
    );

    // The paper's headline comparison: normalize against the zero-RAT
    // ideal configuration.
    let ideal =
        SessionBuilder::new(&tune(paper_ideal(16, MIB))).build()?.run_to_completion();
    println!("ideal:     completion {}", fmt_time(ideal.completion));
    println!(
        "\nreverse-translation overhead: {:.2}x (paper §4.1: up to 1.4x at 1 MB)",
        stats.completion as f64 / ideal.completion as f64
    );

    let f = stats.breakdown.fractions();
    println!(
        "RTT share: fabric {:.0}% | net {:.0}% | translation {:.0}% | memory {:.0}% | ack {:.0}%",
        100.0 * f[0], 100.0 * f[1], 100.0 * f[2], 100.0 * f[3], 100.0 * f[4]
    );
    let c = stats.classes.fig7_fractions();
    println!(
        "outcomes:  l1-hit {:.0}% | l1-mshr-hit {:.0}% | deeper {:.0}%",
        100.0 * c[0], 100.0 * c[1], 100.0 * (c[2] + c[3] + c[4] + c[5])
    );
    Ok(())
}
