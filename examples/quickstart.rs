//! Quickstart: simulate one All-to-All on a 16-GPU UALink pod and print
//! the reverse-translation report.
//!
//! Run with: `cargo run --release --example quickstart`

use ratsim::config::presets::{paper_baseline, paper_ideal};
use ratsim::pod;
use ratsim::util::units::{fmt_time, MIB};

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();

    // Table-1 baseline: 16 GPUs (4 per node), 1 MiB all-pairs All-to-All.
    let cfg = paper_baseline(16, MIB);
    println!("pod: {} GPUs, {} stations/GPU, {} request bytes", cfg.gpus,
        cfg.link.stations_per_gpu, cfg.request_bytes());

    let stats = pod::run(&cfg)?;
    println!("\nbaseline:  {}", stats.summary());

    // The paper's headline comparison: normalize against the zero-RAT
    // ideal configuration.
    let ideal = pod::run(&paper_ideal(16, MIB))?;
    println!("ideal:     completion {}", fmt_time(ideal.completion));
    println!(
        "\nreverse-translation overhead: {:.2}x (paper §4.1: up to 1.4x at 1 MB)",
        stats.completion as f64 / ideal.completion as f64
    );

    let f = stats.breakdown.fractions();
    println!(
        "RTT share: fabric {:.0}% | net {:.0}% | translation {:.0}% | memory {:.0}% | ack {:.0}%",
        100.0 * f[0], 100.0 * f[1], 100.0 * f[2], 100.0 * f[3], 100.0 * f[4]
    );
    let c = stats.classes.fig7_fractions();
    println!(
        "outcomes:  l1-hit {:.0}% | l1-mshr-hit {:.0}% | deeper {:.0}%",
        100.0 * c[0], 100.0 * c[1], 100.0 * (c[2] + c[3] + c[4] + c[5])
    );
    Ok(())
}
