//! §6 optimization ablation: fused pre-translation kernels (§6.1) and
//! software-guided TLB prefetching (§6.2) against the baseline and the
//! zero-RAT ideal, on the latency-sensitive small collectives the paper
//! highlights for inference workloads.
//!
//! Run with: `cargo run --release --example prefetch_opt`
//! (`RATSIM_QUICK=1` trims the request budget for CI smoke runs.)

use ratsim::config::presets::{paper_baseline, paper_ideal};
use ratsim::config::{PodConfig, PrefetchPolicy, RequestSizing};
use ratsim::pod::SessionBuilder;
use ratsim::util::units::{fmt_bytes, to_ns, MIB};

fn tune(mut cfg: PodConfig) -> PodConfig {
    let budget: u64 =
        if std::env::var("RATSIM_QUICK").is_ok() { 20_000 } else { 300_000 };
    cfg.workload.request_sizing = RequestSizing::Auto { target_total_requests: budget };
    cfg
}

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();
    let gpus = 16;
    println!("§6 ablation — {gpus} GPUs\n");
    println!(
        "{:>8}  {:>22}  {:>10}  {:>12}  {:>10}  {:>9}  {:>9}",
        "size", "variant", "overhead_x", "mean_rat_ns", "data_walks", "pf_useful", "pf_late"
    );
    for size in [MIB, 4 * MIB, 16 * MIB] {
        let ideal_ns = to_ns(
            SessionBuilder::new(&tune(paper_ideal(gpus, size)))
                .build()?
                .run_to_completion()
                .completion,
        );
        for variant in
            ["baseline", "pretranslate", "stride-prefetch", "sw-guided", "fused", "sw+stride"]
        {
            let mut cfg = tune(paper_baseline(gpus, size));
            if variant == "pretranslate" {
                cfg.trans.pretranslate.enabled = true;
                cfg.trans.pretranslate.pages_per_pair = 0; // whole stream
            }
            if variant.contains("stride") {
                cfg.trans.prefetch.enabled = true;
                cfg.trans.prefetch.depth = 2;
            }
            if variant.contains("sw") {
                cfg.trans.prefetch_policy = PrefetchPolicy::sw_guided_default();
            }
            if variant == "fused" {
                cfg.trans.prefetch_policy = PrefetchPolicy::Fused;
            }
            cfg.name = format!("{variant}-{}", fmt_bytes(size));
            let s = SessionBuilder::new(&cfg).build()?.run_to_completion();
            let walks =
                s.classes.prim_full_walk + s.classes.prim_pwc_hit.iter().sum::<u64>();
            println!(
                "{:>8}  {:>22}  {:>10.3}  {:>12.1}  {:>10}  {:>9}  {:>9}",
                fmt_bytes(size),
                variant,
                to_ns(s.completion) / ideal_ns,
                s.mean_rat_ns(),
                walks,
                s.prefetch_useful,
                s.prefetch_late
            );
        }
    }
    println!("\nexpected: pre-translation and the §6 hint policies eliminate data-path");
    println!("walks on small collectives (largest relative gain there), while large");
    println!("collectives amortize their walks and see diminishing returns.");
    Ok(())
}
