//! L2 Link-TLB sizing study (the paper's Fig 11 insight): because custom
//! collectives stream through pages with minimal temporal locality, the
//! L2 TLB only needs to cover ~one active page per participating GPU —
//! over-provisioning buys nothing.
//!
//! Run with: `cargo run --release --example tlb_sizing`
//! (`RATSIM_QUICK=1` trims the request budget for CI smoke runs.)

use ratsim::config::presets::{paper_baseline, paper_ideal};
use ratsim::config::RequestSizing;
use ratsim::pod::SessionBuilder;
use ratsim::util::units::{to_ns, MIB};

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();
    let gpus = 32;
    let size = 16 * MIB;
    let budget = RequestSizing::Auto {
        target_total_requests: if std::env::var("RATSIM_QUICK").is_ok() {
            20_000
        } else {
            400_000
        },
    };

    let mut ideal = paper_ideal(gpus, size);
    ideal.workload.request_sizing = budget;
    let ideal_ns = to_ns(SessionBuilder::new(&ideal).build()?.run_to_completion().completion);

    println!("32 GPUs, 16 MiB All-to-All — L2 Link-TLB size sweep\n");
    println!("{:>10}  {:>10}  {:>12}  {:>13}", "l2_entries", "overhead_x", "mean_rat_ns", "touched_pages");
    for l2 in [16u32, 32, 64, 512, 32768] {
        let mut cfg = paper_baseline(gpus, size);
        cfg.workload.request_sizing = budget;
        cfg.trans.l2.entries = l2;
        cfg.name = format!("l2-{l2}");
        let s = SessionBuilder::new(&cfg).build()?.run_to_completion();
        println!(
            "{:>10}  {:>10.3}  {:>12.1}  {:>13}",
            l2,
            to_ns(s.completion) / ideal_ns,
            s.mean_rat_ns(),
            s.max_touched_pages
        );
    }
    println!("\nexpected shape: flat from 32 entries up (≈ #GPUs working set);");
    println!("only capacities below the working set degrade (§4.5).");
    Ok(())
}
