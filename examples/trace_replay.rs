//! Streaming trace replay: feed a synthetic serving trace to the pod
//! one row at a time (lazy admission under a bounded pending-op
//! window), export the same stream to the trace file format, and show
//! the file-backed replay reproducing the run bit-for-bit.
//!
//! Run with: `cargo run --release --example trace_replay`
//! (`RATSIM_QUICK=1` trims the row/request budget for CI smoke runs.)
//!
//! The checked-in `examples/traces/sample_serving.csv` is the
//! file-backed equivalent: `ratsim replay --trace` streams it through
//! the same path (see WORKLOADS.md "Trace catalog").

use ratsim::collective::SyntheticTraceGen;
use ratsim::config::presets::paper_baseline;
use ratsim::config::{RequestSizing, TraceSpec};
use ratsim::pod::SessionBuilder;
use ratsim::stats::RunStats;
use ratsim::util::units::{fmt_time, MIB};

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();
    let quick = std::env::var("RATSIM_QUICK").is_ok();

    // The `serving` preset: Zipf job popularity, log-normal sizes,
    // diurnal-modulated arrivals on a 16-GPU pod.
    let mut spec = TraceSpec::serving_default();
    spec.rows = if quick { 150 } else { 600 };
    spec.jobs = 32;

    let mut cfg = paper_baseline(spec.gpus, MIB);
    cfg.name = format!("trace-replay-{}gpu", spec.gpus);
    cfg.workload.request_sizing = RequestSizing::Auto {
        target_total_requests: if quick { 20_000 } else { 120_000 },
    };
    let window = 1024u32;

    let run = |gen: SyntheticTraceGen| -> anyhow::Result<RunStats> {
        Ok(SessionBuilder::new(&cfg)
            .stream(gen)
            .stream_window(window)
            .build()?
            .run_to_completion())
    };

    // Pass 1: stream straight from the generator. Nothing is
    // materialized up front — rows are lowered and admitted as
    // simulated time reaches their arrivals.
    let stats = run(SyntheticTraceGen::new(&spec)?)?;
    println!("generator stream: {}", stats.summary());
    println!(
        "  {} rows replayed | {} jobs | peak {} pending ops (window {})",
        stats.stream_rows,
        stats.jobs.len(),
        stats.stream_peak_pending_ops,
        stats.stream_window_ops
    );
    let worst = stats.jobs.iter().map(|j| j.rtt_hist.quantile(0.99)).max().unwrap_or(0);
    println!("  worst per-job p99 RTT: {}", fmt_time(worst));

    // Pass 2: export the identical stream to the JSONL trace format and
    // replay it through the file parser — the wire format is lossless,
    // so the run reproduces exactly.
    let mut gen = SyntheticTraceGen::new(&spec)?;
    let text = gen.export_jsonl()?;
    let replayed = run(gen)?;
    let from_file = SessionBuilder::new(&cfg)
        .stream(ratsim::collective::TraceReader::from_string("export", text))
        .stream_window(window)
        .build()?
        .run_to_completion();
    assert_eq!(replayed.completion, stats.completion, "generator replay diverged");
    assert_eq!(from_file.completion, stats.completion, "file replay diverged");
    assert_eq!(from_file.events, stats.events, "file replay event count diverged");
    println!(
        "\nexport -> TraceReader replay: completion {} — bit-identical to the generator",
        fmt_time(from_file.completion)
    );
    Ok(())
}
