//! End-to-end driver: MoE inference on a simulated 16-GPU UALink pod.
//!
//! Proves all three layers compose:
//!   * **L1/L2 (build time)** — `make artifacts` lowered the MoE layer
//!     (with the Pallas expert-FFN kernel inside) and the §6.1
//!     pre-translation page-schedule kernel to HLO text;
//!   * **runtime** — this binary loads both through PJRT and runs the
//!     *actual* expert compute for every simulated GPU shard;
//!   * **L3** — the pod simulator runs the dispatch & combine All-to-Alls
//!     around each layer and reports the paper's headline metric: the
//!     reverse-translation overhead of the communication phases.
//!
//! Per layer: run MoE compute via PJRT → (optionally) feed the page
//! schedule computed by the fused kernel to the pre-translation warmup →
//! simulate dispatch A2A → simulate combine A2A.
//!
//! Run with: `make artifacts && cargo run --release --example moe_inference`

use anyhow::{Context, Result};
use ratsim::config::presets::{paper_baseline, paper_ideal};
use ratsim::config::{PodConfig, RequestSizing};
use ratsim::pod::SessionBuilder;
use ratsim::runtime::{ArtifactManifest, PjrtRuntime};
use ratsim::util::units::{fmt_time, to_us, MIB};
use std::path::Path;

const GPUS: u32 = 16;
const LAYERS: usize = 4;
/// Per-GPU activation payload exchanged by each All-to-All: a
/// latency-sensitive inference-sized collective (§5: small batches).
const A2A_BYTES: u64 = MIB;

fn a2a_config(ideal: bool, pretranslate: bool) -> PodConfig {
    let mut cfg =
        if ideal { paper_ideal(GPUS, A2A_BYTES) } else { paper_baseline(GPUS, A2A_BYTES) };
    cfg.workload.request_sizing = RequestSizing::Auto { target_total_requests: 200_000 };
    if pretranslate {
        cfg.trans.pretranslate.enabled = true;
        cfg.trans.pretranslate.pages_per_pair = 0;
    }
    cfg
}

fn main() -> Result<()> {
    ratsim::util::logger::init();
    let dir = Path::new("artifacts");
    let manifest = ArtifactManifest::load(dir)
        .context("artifacts missing — run `make artifacts` first")?;
    let rt = PjrtRuntime::cpu()?;
    let moe = rt.compile_file(
        manifest.find("moe_layer").context("moe_layer artifact missing")?,
        &manifest.hlo_path(manifest.find("moe_layer").unwrap()),
    )?;
    let sched = rt.compile_file(
        manifest.find("page_schedule").context("page_schedule artifact missing")?,
        &manifest.hlo_path(manifest.find("page_schedule").unwrap()),
    )?;
    println!("PJRT up on {}; artifacts loaded\n", rt.platform());

    // Deterministic per-GPU token shards + shared weights.
    let spec = &moe.spec;
    let gen = |seed: u64, n: usize| -> Vec<f32> {
        let mut rng = ratsim::util::rng::Rng::new(seed);
        (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 0.2).collect()
    };
    let sizes: Vec<usize> =
        spec.input_shapes.iter().map(|s| s.iter().product()).collect();
    let gate_w = gen(1, sizes[1]);
    let w1 = gen(2, sizes[2]);
    let w2 = gen(3, sizes[3]);

    // The fused pre-translation kernel (§6.1): compute the page schedule
    // of the upcoming A2A once per layer — its output drives the warmup.
    let chunk = (A2A_BYTES / GPUS as u64) as f32;
    let bases: Vec<f32> = (0..15).map(|i| i as f32 * chunk).collect();
    let lens: Vec<f32> = vec![chunk; 15];
    let pages = sched.run_f32(&[bases, lens])?;
    let warm_pages: usize = pages[0].iter().filter(|&&p| p >= 0.0).count();
    println!(
        "fused pre-translation kernel: {} streams, {} pages to warm per destination",
        pages[0].len() / 8,
        warm_pages
    );

    let mut compute_us = 0.0f64;
    let mut a2a_base = 0u64;
    let mut a2a_ideal = 0u64;
    let mut a2a_pret = 0u64;

    println!("\nrunning {LAYERS} MoE layers × {GPUS} GPU shards…");
    for layer in 0..LAYERS {
        // L2/L1 compute: every GPU shard's expert FFN through PJRT.
        let t0 = std::time::Instant::now();
        let mut checksum = 0.0f64;
        for gpu in 0..GPUS as u64 {
            let tokens = gen(100 + gpu + layer as u64 * 31, sizes[0]);
            let out = moe.run_f32(&[tokens, gate_w.clone(), w1.clone(), w2.clone()])?;
            checksum += out[0].iter().map(|&v| v as f64).sum::<f64>();
            // Expert loads size the dispatch chunks (all finite & ≥ 0).
            assert!(out[1].iter().all(|&l| (0.0..=spec.input_shapes[0][0] as f32).contains(&l)));
            assert_eq!(out[1].iter().sum::<f32>() as usize, spec.input_shapes[0][0]);
        }
        compute_us += t0.elapsed().as_secs_f64() * 1e6;
        anyhow::ensure!(checksum.is_finite(), "NaN/Inf escaped the MoE layer");

        // L3 communication: dispatch + combine All-to-Alls (2 per layer).
        let a2a = |ideal, pret| -> Result<u64> {
            Ok(SessionBuilder::new(&a2a_config(ideal, pret))
                .build()?
                .run_to_completion()
                .completion)
        };
        for _ in 0..2 {
            a2a_base += a2a(false, false)?;
            a2a_ideal += a2a(true, false)?;
            a2a_pret += a2a(false, true)?;
        }
        println!("  layer {layer}: compute OK, A2A×2 simulated");
    }

    println!("\n== end-to-end report ({LAYERS} layers, {GPUS} GPUs, {}/A2A) ==", "1MiB");
    println!("PJRT expert compute (host wall): {compute_us:.0} us total");
    println!("simulated A2A time, baseline:       {}", fmt_time(a2a_base));
    println!("simulated A2A time, ideal (no RAT): {}", fmt_time(a2a_ideal));
    println!("simulated A2A time, pre-translated: {}", fmt_time(a2a_pret));
    let overhead = a2a_base as f64 / a2a_ideal as f64;
    let recovered = (a2a_base - a2a_pret) as f64 / (a2a_base - a2a_ideal) as f64;
    println!("\nheadline: reverse translation inflates inference A2A time {overhead:.2}x");
    println!(
        "          fused pre-translation recovers {:.0}% of that overhead ({} -> {} per A2A)",
        100.0 * recovered,
        to_us(a2a_base / (2 * LAYERS as u64)),
        to_us(a2a_pret / (2 * LAYERS as u64)),
    );
    anyhow::ensure!(overhead > 1.05, "expected visible RAT overhead");
    anyhow::ensure!(a2a_pret < a2a_base, "pre-translation must help");
    println!("\nmoe_inference OK");
    Ok(())
}
