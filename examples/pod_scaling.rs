//! Pod-size × fabric-topology scaling study: how the reverse-translation
//! overhead and the destination translation working set evolve from 8 to
//! 64 GPUs at a fixed, latency-sensitive collective size (the paper's
//! Fig 4 column read vertically + the §4.4 working-set insight), on each
//! of the three fabrics — the paper's rail Clos, an oversubscribed
//! leaf–spine, and a two-pod scale-out cluster with serialized inter-pod
//! uplinks.
//!
//! Run with: `cargo run --release --example pod_scaling`
//! (`RATSIM_QUICK=1` trims the request budget for CI smoke runs.)

use ratsim::config::presets::{paper_baseline, paper_ideal};
use ratsim::config::{RequestSizing, TopologySpec};
use ratsim::pod::SessionBuilder;
use ratsim::stats::plot::bar_chart;
use ratsim::util::units::{to_ns, MIB};

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();
    let size = MIB;
    let budget: u64 =
        if std::env::var("RATSIM_QUICK").is_ok() { 20_000 } else { 300_000 };
    let mut rows = Vec::new();
    println!(
        "{:>14}  {:>5}  {:>10}  {:>12}  {:>14}  {:>13}",
        "topology", "gpus", "overhead_x", "mean_rat_ns", "internode_frac", "touched_pages"
    );
    for topo in TopologySpec::catalog() {
        for gpus in [8u32, 16, 32, 64] {
            let tune = |mut c: ratsim::config::PodConfig| {
                c.workload.request_sizing = RequestSizing::Auto { target_total_requests: budget };
                c.topology = topo;
                c
            };
            let b = SessionBuilder::new(&tune(paper_baseline(gpus, size)))
                .build()?
                .run_to_completion();
            let i = SessionBuilder::new(&tune(paper_ideal(gpus, size)))
                .build()?
                .run_to_completion();
            let overhead = to_ns(b.completion) / to_ns(i.completion);
            println!(
                "{:>14}  {gpus:>5}  {overhead:>10.3}  {:>12.1}  {:>14.3}  {:>13}",
                topo.label(),
                b.mean_rat_ns(),
                b.internode_requests as f64 / b.requests as f64,
                b.max_touched_pages
            );
            if gpus == 64 {
                rows.push((format!("{} 64 GPUs", topo.label()), overhead));
            }
        }
    }
    print!("{}", bar_chart("RAT overhead vs ideal @ 1MiB, 64 GPUs", &rows, 48));
    println!("\nlarger pods raise the inter-node share of traffic (4 GPUs/node),");
    println!("keeping the cold-walk penalty pinned to the critical path (§4.1);");
    println!("normalizing each fabric against its own ideal isolates the RAT cost");
    println!("from the extra spine / inter-pod hop latency the topology itself adds.");
    Ok(())
}
