//! Pod-size scaling study: how the reverse-translation overhead and the
//! destination translation working set evolve from 8 to 64 GPUs at a
//! fixed, latency-sensitive collective size (the paper's Fig 4 column
//! read vertically + the §4.4 working-set insight).
//!
//! Run with: `cargo run --release --example pod_scaling`
//! (`RATSIM_QUICK=1` trims the request budget for CI smoke runs.)

use ratsim::config::presets::{paper_baseline, paper_ideal};
use ratsim::config::RequestSizing;
use ratsim::pod::SessionBuilder;
use ratsim::stats::plot::bar_chart;
use ratsim::util::units::{to_ns, MIB};

fn main() -> anyhow::Result<()> {
    ratsim::util::logger::init();
    let size = MIB;
    let budget: u64 =
        if std::env::var("RATSIM_QUICK").is_ok() { 20_000 } else { 300_000 };
    let mut rows = Vec::new();
    println!("{:>5}  {:>10}  {:>12}  {:>14}  {:>13}", "gpus", "overhead_x", "mean_rat_ns", "internode_frac", "touched_pages");
    for gpus in [8u32, 16, 32, 64] {
        let tune = |mut c: ratsim::config::PodConfig| {
            c.workload.request_sizing = RequestSizing::Auto { target_total_requests: budget };
            c
        };
        let b = SessionBuilder::new(&tune(paper_baseline(gpus, size)))
            .build()?
            .run_to_completion();
        let i = SessionBuilder::new(&tune(paper_ideal(gpus, size)))
            .build()?
            .run_to_completion();
        let overhead = to_ns(b.completion) / to_ns(i.completion);
        println!(
            "{gpus:>5}  {overhead:>10.3}  {:>12.1}  {:>14.3}  {:>13}",
            b.mean_rat_ns(),
            b.internode_requests as f64 / b.requests as f64,
            b.max_touched_pages
        );
        rows.push((format!("{gpus} GPUs"), overhead));
    }
    print!("{}", bar_chart("RAT overhead vs ideal @ 1MiB", &rows, 48));
    println!("\nlarger pods raise the inter-node share of traffic (4 GPUs/node),");
    println!("keeping the cold-walk penalty pinned to the critical path (§4.1).");
    Ok(())
}
