//! Regenerates the warm-up study (cold vs steady-state iteration).
mod bench_common;
use ratsim::harness::warmup;

fn main() {
    bench_common::run_figure("warmup_iters", warmup);
}
