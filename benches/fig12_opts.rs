//! Regenerates Fig 12 (§6 translation hiding: sw-guided prefetch + fused
//! pre-translation vs baseline/ideal, with hint counters).
mod bench_common;
use ratsim::harness::fig12_opts;

fn main() {
    bench_common::run_figure("fig12_opts", fig12_opts);
}
