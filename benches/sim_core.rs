//! Simulator-core performance microbenches (the §Perf hot paths):
//! event-queue ops, end-to-end events/second, and the standard pod
//! workloads used for the optimization log in EXPERIMENTS.md §Perf.

use ratsim::config::presets::paper_baseline;
use ratsim::config::RequestSizing;
use ratsim::pod;
use ratsim::sim::EventQueue;
use ratsim::util::minibench::{bench, bench_items, print_header, print_result, BenchConfig};
use ratsim::util::rng::Rng;
use std::time::Duration;

fn main() {
    ratsim::util::logger::init_with_level(log::LevelFilter::Warn);
    print_header("sim core microbenches");
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 20,
        max_time: Duration::from_secs(8),
    };

    // Event queue: push+pop throughput at a realistic pending-set size.
    let mut rng = Rng::new(7);
    let times: Vec<u64> = (0..100_000).map(|_| rng.gen_range(1_000_000)).collect();
    let r = bench_items("eventqueue_100k_push_pop", &cfg, times.len() as u64, || {
        let mut q = EventQueue::with_capacity(times.len());
        for (i, &t) in times.iter().enumerate() {
            q.push(t, i as u64, i as u32);
        }
        while q.pop().is_some() {}
    });
    print_result(&r);

    // Steady-state churn: hold 50k pending, push+pop 100k more.
    let r = bench_items("eventqueue_churn_50k_hold", &cfg, 100_000, || {
        let mut q = EventQueue::with_capacity(64 * 1024);
        let mut seq = 0u64;
        let mut rng = Rng::new(3);
        let mut now = 0u64;
        for _ in 0..50_000 {
            q.push(now + rng.gen_range(10_000), seq, ());
            seq += 1;
        }
        for _ in 0..100_000 {
            let (t, _) = q.pop().unwrap();
            now = t;
            q.push(now + rng.gen_range(10_000), seq, ());
            seq += 1;
        }
    });
    print_result(&r);

    // Whole-pod events/second on the standard perf workloads.
    print_header("pod simulation throughput (events/second)");
    for (name, gpus, size_mib, reqs) in [
        ("pod_16gpu_1MiB_full_fidelity", 16u32, 1u64, 0u64),
        ("pod_16gpu_64MiB_500k_reqs", 16, 64, 500_000),
        ("pod_64gpu_16MiB_500k_reqs", 64, 16, 500_000),
    ] {
        let mut pc = paper_baseline(gpus, size_mib * (1 << 20));
        if reqs > 0 {
            pc.workload.request_sizing = RequestSizing::Auto { target_total_requests: reqs };
        }
        let events = std::cell::Cell::new(0u64);
        let r = bench(name, &cfg, || {
            let s = pod::run(&pc).expect("pod run");
            events.set(s.events);
        });
        let evps = events.get() as f64 / r.mean.as_secs_f64();
        print_result(&r);
        println!("  -> {} events/run, {:.2}M events/s", events.get(), evps / 1e6);
    }
}
