//! Simulator-core performance microbenches (the §Perf hot paths):
//! pending-set ops (4-ary heap vs timing wheel), end-to-end pod
//! events/second on the standard perf workloads, the fused-vs-per-hop
//! engine comparison used for the optimization log in EXPERIMENTS.md
//! §Perf, and the sharded-vs-fused wall-clock comparison at 1024 GPUs
//! (the parallel in-run engine's speedup curve, serial dispatch vs
//! conflict-free parallel handler dispatch).
//!
//! Env knobs:
//! * `RATSIM_BENCH_QUICK=1` — trimmed iterations/request budgets (CI smoke).
//! * `RATSIM_BENCH_OUT=path` — write the aggregate BENCHJSON snapshot
//!   (the format of `BENCH_baseline.json`) to `path`.
//! * `RATSIM_BENCH_DIFF=path` — write the baseline-comparison diff JSON
//!   (per-benchmark throughput ratio + ok/regressed/improved status).
//! * `RATSIM_BENCH_TOLERANCE=0.25` — relative band for that status.
//! * `RATSIM_BENCH_ENFORCE=1` — exit nonzero on a regressed benchmark
//!   (advisory by default; shared CI runners are noisy).
//!
//! A final section always prints the current-vs-baseline throughput
//! ratio per workload (reqs/s where recorded, else events/s or items/s);
//! entries whose baseline is a `null` placeholder report `no-baseline`.

mod bench_common;

use ratsim::config::presets::paper_baseline;
use ratsim::config::{EnginePolicy, PodConfig, RequestSizing, TopologySpec};
use ratsim::pod::SessionBuilder;
use ratsim::sim::{EventQueue, TimingWheel};
use ratsim::stats::RunStats;
use ratsim::util::json::Json;
use ratsim::util::minibench::{bench_items, print_header, print_result, BenchConfig};
use ratsim::util::rng::Rng;
use std::time::Duration;

fn quick() -> bool {
    std::env::var("RATSIM_BENCH_QUICK").is_ok()
}

/// One session-backed run of a config's collective.
fn run_pod(cfg: &PodConfig) -> RunStats {
    SessionBuilder::new(cfg).build().expect("pod session").run_to_completion()
}

fn main() {
    ratsim::util::logger::init_with_level(log::LevelFilter::Warn);
    let cfg = if quick() {
        BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 3,
            max_time: Duration::from_secs(2),
        }
    } else {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            max_time: Duration::from_secs(8),
        }
    };
    let mut records: Vec<Json> = Vec::new();

    print_header("pending-set microbenches (4-ary heap vs timing wheel)");
    let mut rng = Rng::new(7);
    let times: Vec<u64> = (0..100_000).map(|_| rng.gen_range(1_000_000)).collect();

    // Bulk load + full drain at a realistic pending-set size.
    let r = bench_items("eventqueue_100k_push_pop", &cfg, times.len() as u64, || {
        let mut q = EventQueue::with_capacity(times.len());
        for (i, &t) in times.iter().enumerate() {
            q.push(t, i as u64, i as u32);
        }
        while q.pop().is_some() {}
    });
    print_result(&r);
    records.push(r.to_json());

    let r = bench_items("wheel_100k_push_pop", &cfg, times.len() as u64, || {
        let mut q = TimingWheel::new();
        for (i, &t) in times.iter().enumerate() {
            q.push(t, i as u64, i as u32);
        }
        while q.pop().is_some() {}
    });
    print_result(&r);
    records.push(r.to_json());

    // Steady-state churn: hold 50k pending, push+pop 100k more.
    let r = bench_items("eventqueue_churn_50k_hold", &cfg, 100_000, || {
        let mut q = EventQueue::with_capacity(64 * 1024);
        let mut seq = 0u64;
        let mut rng = Rng::new(3);
        let mut now = 0u64;
        for _ in 0..50_000 {
            q.push(now + rng.gen_range(10_000), seq, ());
            seq += 1;
        }
        for _ in 0..100_000 {
            let (t, _, _) = q.pop().unwrap();
            now = t;
            q.push(now + rng.gen_range(10_000), seq, ());
            seq += 1;
        }
    });
    print_result(&r);
    records.push(r.to_json());

    let r = bench_items("wheel_churn_50k_hold", &cfg, 100_000, || {
        let mut q = TimingWheel::with_capacity(64 * 1024);
        let mut seq = 0u64;
        let mut rng = Rng::new(3);
        let mut now = 0u64;
        for _ in 0..50_000 {
            q.push(now + rng.gen_range(10_000), seq, ());
            seq += 1;
        }
        for _ in 0..100_000 {
            let (t, _, _) = q.pop().unwrap();
            now = t;
            q.push(now + rng.gen_range(10_000), seq, ());
            seq += 1;
        }
    });
    print_result(&r);
    records.push(r.to_json());

    // Whole-pod events/second on the standard perf workloads (fused
    // engine — the default), plus a single per-hop reference run each so
    // the fusion speedup is visible in-place.
    print_header("pod simulation throughput (events/second, fused engine)");
    for (name, gpus, size_mib, reqs, topology) in [
        ("pod_16gpu_1MiB_full_fidelity", 16u32, 1u64, 0u64, TopologySpec::RailClos),
        ("pod_16gpu_64MiB_500k_reqs", 16, 64, 500_000, TopologySpec::RailClos),
        ("pod_64gpu_16MiB_500k_reqs", 64, 16, 500_000, TopologySpec::RailClos),
        // The collective-algorithm layer's hot shape: a 2(N-1)-phase ring
        // AllReduce pipeline (long `after` chains instead of the flat
        // all-pairs burst).
        ("pod_64gpu_allreduce_ring_16MiB", 64, 16, 500_000, TopologySpec::RailClos),
        ("pod_256gpu_16MiB_500k_reqs", 256, 16, 500_000, TopologySpec::RailClos),
        // The fabric-layer workloads: the same 64-GPU cell on the
        // multi-tier topologies (4-serializing-hop cross-pod chains /
        // the shared spine tier).
        ("pod_64gpu_2pod_16MiB_500k_reqs", 64, 16, 500_000, TopologySpec::multi_pod_default()),
        (
            "pod_64gpu_leafspine_16MiB_500k_reqs",
            64,
            16,
            500_000,
            TopologySpec::leaf_spine_default(),
        ),
    ] {
        let mut pc = paper_baseline(gpus, size_mib * (1 << 20));
        pc.topology = topology;
        if name.contains("allreduce_ring") {
            pc.workload.collective = ratsim::config::CollectiveKind::AllReduce;
            pc.workload.algo = Some(ratsim::config::CollectiveAlgo::Ring);
        }
        let target = if quick() {
            Some(30_000)
        } else if reqs > 0 {
            Some(reqs)
        } else {
            None
        };
        if let Some(t) = target {
            pc.workload.request_sizing = RequestSizing::Auto { target_total_requests: t };
        }
        // One counted run up front: event/request volumes for throughput.
        let s0 = run_pod(&pc);
        let (events, requests) = (s0.events, s0.requests);
        let r = bench_items(name, &cfg, events, || {
            run_pod(&pc);
        });
        print_result(&r);
        let evps = events as f64 / r.mean.as_secs_f64();
        let rps = requests as f64 / r.mean.as_secs_f64();
        println!(
            "  -> {events} events/run ({requests} requests), {:.2}M events/s, {:.2}M reqs/s",
            evps / 1e6,
            rps / 1e6
        );
        let mut ph = pc.clone();
        ph.engine = EnginePolicy::PerHop;
        let t0 = std::time::Instant::now();
        let sp = run_pod(&ph);
        let ph_wall = t0.elapsed().as_secs_f64();
        println!(
            "  -> per-hop reference: {} events in {:.2}s ({:.2}x fused wall, {:.2}x events)",
            sp.events,
            ph_wall,
            ph_wall / r.mean.as_secs_f64(),
            sp.events as f64 / events as f64
        );
        let mut j = r.to_json();
        j.set("events", Json::from(events));
        j.set("requests", Json::from(requests));
        j.set("events_per_sec", Json::from(evps));
        j.set("requests_per_sec", Json::from(rps));
        j.set("per_hop_events", Json::from(sp.events));
        j.set("per_hop_wall_seconds", Json::from(ph_wall));
        records.push(j);
    }

    // Multi-tenant serving workload (the tenancy axis): a 64-GPU pod
    // shared by a 3-decode + 1-prefill inference mix, run through
    // a workload session (per-job accounting + cross-job eviction
    // tracking on the hot path).
    print_header("multi-tenant workload throughput (events/second)");
    {
        use ratsim::collective::workload::Workload;
        use ratsim::config::presets::inference_mix_spec;
        let name = "pod_64gpu_4job_mix_500k_reqs";
        let mut pc = paper_baseline(64, 64 << 20);
        pc.name = name.into();
        let target = if quick() { 30_000 } else { 500_000 };
        pc.workload.request_sizing = RequestSizing::Auto { target_total_requests: target };
        let spec = inference_mix_spec(3, 1);
        let workload =
            Workload::from_spec(&spec, 64, pc.trans.page_bytes).expect("workload build");
        let run_workload = |w: Workload| -> RunStats {
            SessionBuilder::new(&pc)
                .workload(w)
                .build()
                .expect("workload session")
                .run_to_completion()
        };
        let s0 = run_workload(workload.clone());
        let (events, requests) = (s0.events, s0.requests);
        let r = bench_items(name, &cfg, events, || {
            run_workload(workload.clone());
        });
        print_result(&r);
        let evps = events as f64 / r.mean.as_secs_f64();
        let rps = requests as f64 / r.mean.as_secs_f64();
        println!(
            "  -> {events} events/run ({requests} requests, {} jobs, {} cross-job L2 evictions), {:.2}M events/s, {:.2}M reqs/s",
            s0.jobs.len(),
            s0.cross_job_l2_evictions,
            evps / 1e6,
            rps / 1e6
        );
        let mut j = r.to_json();
        j.set("events", Json::from(events));
        j.set("requests", Json::from(requests));
        j.set("events_per_sec", Json::from(evps));
        j.set("requests_per_sec", Json::from(rps));
        j.set("jobs", Json::from(s0.jobs.len() as u64));
        records.push(j);
    }

    // Streaming trace replay (the lazy-admission workload source): the
    // same 64-GPU pod fed by the synthetic serving generator, rows
    // admitted as sim time reaches their arrivals under the bounded
    // window — the bench covers the prescan + pump path end to end.
    print_header("streaming trace replay throughput (events/second)");
    {
        use ratsim::collective::SyntheticTraceGen;
        use ratsim::config::TraceSpec;
        let name = "pod_64gpu_trace_replay";
        let mut spec = TraceSpec::serving_default();
        spec.gpus = 64;
        spec.group = 8;
        spec.rows = if quick() { 200 } else { 1500 };
        let mut pc = paper_baseline(64, 1 << 20);
        pc.name = name.into();
        let target = if quick() { 30_000 } else { 500_000 };
        pc.workload.request_sizing = RequestSizing::Auto { target_total_requests: target };
        let run_stream = |pc: &PodConfig, spec: &TraceSpec| -> RunStats {
            SessionBuilder::new(pc)
                .stream(SyntheticTraceGen::new(spec).expect("trace spec"))
                .build()
                .expect("stream session")
                .run_to_completion()
        };
        let s0 = run_stream(&pc, &spec);
        let (events, requests) = (s0.events, s0.requests);
        let r = bench_items(name, &cfg, events, || {
            run_stream(&pc, &spec);
        });
        print_result(&r);
        let evps = events as f64 / r.mean.as_secs_f64();
        let rps = requests as f64 / r.mean.as_secs_f64();
        println!(
            "  -> {events} events/run ({requests} requests, {} rows, peak {} / window {} pending ops), {:.2}M events/s, {:.2}M reqs/s",
            s0.stream_rows,
            s0.stream_peak_pending_ops,
            s0.stream_window_ops,
            evps / 1e6,
            rps / 1e6
        );
        let mut j = r.to_json();
        j.set("events", Json::from(events));
        j.set("requests", Json::from(requests));
        j.set("events_per_sec", Json::from(evps));
        j.set("requests_per_sec", Json::from(rps));
        j.set("rows", Json::from(s0.stream_rows));
        records.push(j);
    }

    // Sharded-vs-fused wall clock at pod scale: the parallel in-run
    // engine's reason to exist. All-pairs A2A at 1024 GPUs floors at one
    // request per pair op (~1.05M requests) — a pending set far past any
    // paper cell — and the sharded engine must reproduce the fused run
    // bit-for-bit while draining it across cores.
    print_header("sharded engine at pod scale (1024 GPUs, wall-clock vs fused)");
    {
        let mut pc = paper_baseline(1024, 1 << 20);
        pc.name = "pod_1024gpu_1MiB".into();
        pc.workload.request_sizing =
            RequestSizing::Auto { target_total_requests: 1_000_000 };
        let s0 = run_pod(&pc);
        let (events, requests) = (s0.events, s0.requests);
        let fused = bench_items("pod_1024gpu_1MiB_fused", &cfg, events, || {
            run_pod(&pc);
        });
        print_result(&fused);
        println!(
            "  -> {events} events/run ({requests} requests), {:.2}M events/s",
            events as f64 / fused.mean.as_secs_f64() / 1e6
        );
        let mut j = fused.to_json();
        j.set("events", Json::from(events));
        j.set("requests", Json::from(requests));
        j.set("events_per_sec", Json::from(events as f64 / fused.mean.as_secs_f64()));
        j.set("requests_per_sec", Json::from(requests as f64 / fused.mean.as_secs_f64()));
        records.push(j);
        let thread_axis: &[u32] = if quick() { &[4] } else { &[2, 4, 8] };
        for &threads in thread_axis {
            // Serial dispatch first: the parallel pending-set drain alone
            // (`sharded:N:serial`) — the denominator for the parallel-
            // dispatch speedup below.
            let mut serial_cfg = pc.clone();
            serial_cfg.engine = EnginePolicy::Sharded { threads, parallel_dispatch: false };
            // Cheap in-bench sanity (the full grid is pinned in
            // rust/tests/engine_diff.rs): same completion, same stream.
            let s1 = run_pod(&serial_cfg);
            assert_eq!(s1.completion, s0.completion, "sharded diverged from fused");
            assert_eq!(s1.events, events, "sharded event count diverged");
            let name = format!("pod_1024gpu_1MiB_sharded{threads}");
            let serial = bench_items(&name, &cfg, events, || {
                run_pod(&serial_cfg);
            });
            print_result(&serial);
            let serial_speedup = fused.mean.as_secs_f64() / serial.mean.as_secs_f64();
            println!("  -> {serial_speedup:.2}x fused wall at {threads} threads (serial dispatch)");
            let mut j = serial.to_json();
            j.set("events", Json::from(events));
            j.set("requests", Json::from(requests));
            j.set("events_per_sec", Json::from(events as f64 / serial.mean.as_secs_f64()));
            j.set("requests_per_sec", Json::from(requests as f64 / serial.mean.as_secs_f64()));
            j.set("threads", Json::from(threads as u64));
            j.set("speedup_vs_fused", Json::from(serial_speedup));
            records.push(j);

            // Parallel dispatch (the default `sharded:N`): conflict-free
            // handler batches execute on worker threads too.
            let mut pd_cfg = pc.clone();
            pd_cfg.engine = EnginePolicy::sharded(threads);
            let s2 = run_pod(&pd_cfg);
            assert_eq!(s2.completion, s0.completion, "parallel dispatch diverged from fused");
            assert_eq!(s2.events, events, "parallel dispatch event count diverged");
            let name = format!("pod_1024gpu_1MiB_sharded{threads}_pdisp");
            let r = bench_items(&name, &cfg, events, || {
                run_pod(&pd_cfg);
            });
            print_result(&r);
            let speedup_fused = fused.mean.as_secs_f64() / r.mean.as_secs_f64();
            let speedup_serial = serial.mean.as_secs_f64() / r.mean.as_secs_f64();
            println!(
                "  -> {speedup_fused:.2}x fused / {speedup_serial:.2}x serial-dispatch wall \
                 at {threads} threads"
            );
            let mut j = r.to_json();
            j.set("events", Json::from(events));
            j.set("requests", Json::from(requests));
            j.set("events_per_sec", Json::from(events as f64 / r.mean.as_secs_f64()));
            j.set("requests_per_sec", Json::from(requests as f64 / r.mean.as_secs_f64()));
            j.set("threads", Json::from(threads as u64));
            j.set("speedup_vs_fused", Json::from(speedup_fused));
            j.set("speedup_vs_serial_dispatch", Json::from(speedup_serial));
            records.push(j);
        }
    }

    // Perf-trajectory tracking: compare throughput (reqs/s where the
    // workload reports it, else events/s or items/s) against the recorded
    // snapshot with a relative tolerance, and emit the diff both to
    // stdout and — via RATSIM_BENCH_DIFF — as a JSON artifact CI uploads.
    let baseline_path = std::path::Path::new("BENCH_baseline.json");
    let baseline = bench_common::load_baseline_records(baseline_path);
    let tolerance: f64 = std::env::var("RATSIM_BENCH_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    let diff = bench_common::bench_diff(&records, &baseline, tolerance);
    let regressions = bench_common::print_diff(&diff);
    if baseline.is_empty() {
        println!(
            "\nBENCH_baseline.json carries no recorded numbers on this checkout — \
             record one with RATSIM_BENCH_OUT=BENCH_baseline.json cargo bench --bench sim_core \
             (the CI bench-smoke job regenerates and uploads a fresh snapshot + diff per run)"
        );
    }
    if let Ok(out) = std::env::var("RATSIM_BENCH_DIFF") {
        ratsim::util::fs::write_atomic(std::path::Path::new(&out), diff.to_string_pretty())
            .expect("write bench diff");
        println!("\nwrote baseline diff to {out}");
    }

    if let Ok(out) = std::env::var("RATSIM_BENCH_OUT") {
        let path = std::path::PathBuf::from(&out);
        bench_common::write_benchjson_file(&path, records).expect("write BENCHJSON snapshot");
        println!("\nwrote BENCHJSON snapshot to {out}");
    }

    // Advisory by default (shared CI runners are noisy); export
    // RATSIM_BENCH_ENFORCE=1 to turn tolerance violations into a failure.
    if regressions > 0 && std::env::var("RATSIM_BENCH_ENFORCE").is_ok() {
        eprintln!("{regressions} benchmark(s) regressed beyond the ±{tolerance:.2} tolerance");
        std::process::exit(1);
    }
}
