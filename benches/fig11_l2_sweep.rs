//! Regenerates Fig 11 (L2 Link-TLB size sweep, 32 GPUs).
mod bench_common;
use ratsim::harness::fig11;

fn main() {
    bench_common::run_figure("fig11_l2_sweep", fig11);
}
