//! Regenerates Fig 5 (mean RAT latency per request) on quick axes.
mod bench_common;
use ratsim::harness::{fig5, main_sweep};

fn main() {
    bench_common::run_figure("fig5_latency", |o| {
        let sweep = main_sweep(o)?;
        fig5(o, &sweep)
    });
}
