//! Regenerates the design-choice ablation (page size / walkers / MSHRs /
//! L1 reach / PWC) — the sensitivity study behind DESIGN.md's knobs.
mod bench_common;
use ratsim::harness::design_ablation;

fn main() {
    bench_common::run_figure("ablation_design", design_ablation);
}
