//! Regenerates Fig 6 (RTT component fractions, 16 GPUs).
mod bench_common;
use ratsim::harness::{breakdown_sweep, fig6};

fn main() {
    bench_common::run_figure("fig6_breakdown", |o| {
        let sweep = breakdown_sweep(o)?;
        fig6(o, &sweep)
    });
}
