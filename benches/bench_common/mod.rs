//! Shared scaffolding for the bench binaries (`harness = false`).
//!
//! Each figure bench regenerates one paper table/figure in `--quick` axes
//! and reports wall time + simulator throughput via `util::minibench`,
//! so `cargo bench | tee bench_output.txt` reproduces every figure's data
//! alongside its cost. `sim_core` additionally aggregates its BENCHJSON
//! records into a snapshot file (`write_benchjson_file`) and compares
//! against the checked-in `BENCH_baseline.json` (`load_baseline`), which
//! tracks the perf trajectory PR over PR.

// Each bench binary compiles this module independently and uses a subset
// of it; unused-item warnings here would be false positives.
#![allow(dead_code)]

use ratsim::harness::FigOpts;
use ratsim::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

pub fn opts() -> FigOpts {
    FigOpts { out_dir: std::path::PathBuf::from("results/bench"), quick: true }
}

/// Run a figure generator once, print its table and timing line.
pub fn run_figure<F>(name: &str, f: F)
where
    F: FnOnce(&FigOpts) -> anyhow::Result<ratsim::harness::Table>,
{
    ratsim::util::logger::init();
    let o = opts();
    std::fs::create_dir_all(&o.out_dir).ok();
    let t0 = Instant::now();
    match f(&o) {
        Ok(table) => {
            table.print();
            println!(
                "\nBENCH {name}: regenerated in {:.2}s (CSV under {})",
                t0.elapsed().as_secs_f64(),
                o.out_dir.display()
            );
        }
        Err(e) => {
            eprintln!("BENCH {name} FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Write an aggregate BENCHJSON snapshot: one object per benchmark (the
/// same records the `BENCHJSON` stdout lines carry), plus provenance.
pub fn write_benchjson_file(path: &Path, records: Vec<Json>) -> std::io::Result<()> {
    let mut top = Json::obj();
    top.set("format", Json::from("ratsim-benchjson-v1"));
    top.set("results", Json::Arr(records));
    std::fs::write(path, top.to_string_pretty())
}

/// Load a BENCHJSON snapshot, returning `name → (mean_ns, events_per_sec)`
/// for every record that actually carries numbers (placeholder snapshots
/// with `null` fields contribute nothing).
pub fn load_baseline(path: &Path) -> BTreeMap<String, (f64, f64)> {
    let mut map = BTreeMap::new();
    let Ok(text) = std::fs::read_to_string(path) else {
        return map;
    };
    let Ok(j) = Json::parse(&text) else {
        return map;
    };
    let Some(results) = j.get("results").and_then(Json::as_arr) else {
        return map;
    };
    for r in results {
        let name = r.get("name").and_then(Json::as_str);
        let mean = r.get("mean_ns").and_then(Json::as_f64);
        // Pod workloads record events/s explicitly; the pending-set
        // microbenches carry it as minibench's items_per_sec.
        let evps = r
            .get("events_per_sec")
            .or_else(|| r.get("items_per_sec"))
            .and_then(Json::as_f64);
        if let (Some(name), Some(mean), Some(evps)) = (name, mean, evps) {
            map.insert(name.to_string(), (mean, evps));
        }
    }
    map
}
