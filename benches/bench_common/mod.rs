//! Shared scaffolding for the figure benches (`harness = false`).
//!
//! Each bench binary regenerates one paper table/figure in `--quick` axes
//! and reports wall time + simulator throughput via `util::minibench`,
//! so `cargo bench | tee bench_output.txt` reproduces every figure's data
//! alongside its cost.

use ratsim::harness::FigOpts;
use std::time::Instant;

pub fn opts() -> FigOpts {
    FigOpts { out_dir: std::path::PathBuf::from("results/bench"), quick: true }
}

/// Run a figure generator once, print its table and timing line.
pub fn run_figure<F>(name: &str, f: F)
where
    F: FnOnce(&FigOpts) -> anyhow::Result<ratsim::harness::Table>,
{
    ratsim::util::logger::init();
    let o = opts();
    std::fs::create_dir_all(&o.out_dir).ok();
    let t0 = Instant::now();
    match f(&o) {
        Ok(table) => {
            table.print();
            println!(
                "\nBENCH {name}: regenerated in {:.2}s (CSV under {})",
                t0.elapsed().as_secs_f64(),
                o.out_dir.display()
            );
        }
        Err(e) => {
            eprintln!("BENCH {name} FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}
