//! Shared scaffolding for the bench binaries (`harness = false`).
//!
//! Each figure bench regenerates one paper table/figure in `--quick` axes
//! and reports wall time + simulator throughput via `util::minibench`,
//! so `cargo bench | tee bench_output.txt` reproduces every figure's data
//! alongside its cost. `sim_core` additionally aggregates its BENCHJSON
//! records into a snapshot file (`write_benchjson_file`) and diffs its
//! throughput against the checked-in `BENCH_baseline.json`
//! (`load_baseline_records` + `bench_diff`/`print_diff`), which tracks
//! the perf trajectory PR over PR.

// Each bench binary compiles this module independently and uses a subset
// of it; unused-item warnings here would be false positives.
#![allow(dead_code)]

use ratsim::harness::FigOpts;
use ratsim::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

pub fn opts() -> FigOpts {
    FigOpts { out_dir: std::path::PathBuf::from("results/bench"), quick: true }
}

/// Run a figure generator once, print its table and timing line.
pub fn run_figure<F>(name: &str, f: F)
where
    F: FnOnce(&FigOpts) -> anyhow::Result<ratsim::harness::Table>,
{
    ratsim::util::logger::init();
    let o = opts();
    std::fs::create_dir_all(&o.out_dir).ok();
    let t0 = Instant::now();
    match f(&o) {
        Ok(table) => {
            table.print();
            println!(
                "\nBENCH {name}: regenerated in {:.2}s (CSV under {})",
                t0.elapsed().as_secs_f64(),
                o.out_dir.display()
            );
        }
        Err(e) => {
            eprintln!("BENCH {name} FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Write an aggregate BENCHJSON snapshot: one object per benchmark (the
/// same records the `BENCHJSON` stdout lines carry), plus provenance.
pub fn write_benchjson_file(path: &Path, records: Vec<Json>) -> std::io::Result<()> {
    let mut top = Json::obj();
    top.set("format", Json::from("ratsim-benchjson-v1"));
    top.set("results", Json::Arr(records));
    // Atomic: a crash (or a concurrent reader) never sees a half-written
    // snapshot.
    ratsim::util::fs::write_atomic(path, top.to_string_pretty())
}

/// Load a BENCHJSON snapshot as raw records by name (every record kept,
/// including `null` placeholders — the diff reports those as
/// `no-baseline`). Missing or unparsable files yield an empty map.
pub fn load_baseline_records(path: &Path) -> BTreeMap<String, Json> {
    let mut map = BTreeMap::new();
    let Ok(text) = std::fs::read_to_string(path) else {
        return map;
    };
    let Ok(j) = Json::parse(&text) else {
        return map;
    };
    let Some(results) = j.get("results").and_then(Json::as_arr) else {
        return map;
    };
    for r in results {
        if let Some(name) = r.get("name").and_then(Json::as_str) {
            map.insert(name.to_string(), r.clone());
        }
    }
    map
}

/// The throughput metric a record carries, by preference: requests/s for
/// pod workloads, events/s for whole-pod runs, items/s for the pending-set
/// microbenches.
const THROUGHPUT_KEYS: &[&str] = &["requests_per_sec", "events_per_sec", "items_per_sec"];

fn throughput_of(record: &Json) -> Option<(&'static str, f64)> {
    THROUGHPUT_KEYS
        .iter()
        .find_map(|&k| record.get(k).and_then(Json::as_f64).map(|v| (k, v)))
}

/// First throughput metric carried by *both* records (so a baseline
/// recorded in an older, events/s-only format still gets compared
/// instead of reported `no-baseline`).
fn shared_throughput(current: &Json, base: &Json) -> Option<(&'static str, f64, f64)> {
    THROUGHPUT_KEYS.iter().find_map(|&k| {
        match (current.get(k).and_then(Json::as_f64), base.get(k).and_then(Json::as_f64)) {
            (Some(c), Some(b)) => Some((k, c, b)),
            _ => None,
        }
    })
}

/// Compare current records against a recorded baseline: for every record
/// sharing a throughput metric with its baseline entry, report the ratio
/// and whether it left the ±`tolerance` band. Returns a JSON document —
/// the `bench_diff.json` artifact the CI bench-smoke job uploads.
pub fn bench_diff(
    records: &[Json],
    baseline: &BTreeMap<String, Json>,
    tolerance: f64,
) -> Json {
    let mut rows = Vec::new();
    for r in records {
        let name = r.get("name").and_then(Json::as_str).unwrap_or("?");
        let mut row = Json::obj();
        row.set("name", Json::from(name));
        let shared = baseline.get(name).and_then(|b| shared_throughput(r, b));
        match shared {
            Some((key, cur, b)) if b > 0.0 => {
                let ratio = cur / b;
                row.set("metric", Json::from(key));
                row.set("current", Json::from(cur));
                row.set("baseline", Json::from(b));
                row.set("ratio", Json::from(ratio));
                let status = if ratio < 1.0 - tolerance {
                    "regressed"
                } else if ratio > 1.0 + tolerance {
                    "improved"
                } else {
                    "ok"
                };
                row.set("status", Json::from(status));
            }
            _ => match throughput_of(r) {
                Some((key, cur)) => {
                    row.set("metric", Json::from(key));
                    row.set("current", Json::from(cur));
                    row.set("status", Json::from("no-baseline"));
                }
                None => {
                    row.set("status", Json::from("no-metric"));
                }
            },
        }
        rows.push(row);
    }
    let mut top = Json::obj();
    top.set("format", Json::from("ratsim-benchdiff-v1"));
    top.set("tolerance", Json::from(tolerance));
    top.set("results", Json::Arr(rows));
    top
}

/// Print a [`bench_diff`] document to stdout; returns the number of
/// entries whose status is `regressed`.
pub fn print_diff(diff: &Json) -> usize {
    let Some(rows) = diff.get("results").and_then(Json::as_arr) else {
        return 0;
    };
    let tol = diff.get("tolerance").and_then(Json::as_f64).unwrap_or(0.0);
    println!("\n== vs BENCH_baseline.json (tolerance ±{:.0}%) ==", 100.0 * tol);
    let mut regressed = 0;
    let mut missing: Vec<&str> = Vec::new();
    for row in rows {
        let name = row.get("name").and_then(Json::as_str).unwrap_or("?");
        let status = row.get("status").and_then(Json::as_str).unwrap_or("?");
        match row.get("ratio").and_then(Json::as_f64) {
            Some(ratio) => {
                let metric = row.get("metric").and_then(Json::as_str).unwrap_or("?");
                println!("  {name}: {ratio:.2}x {metric} vs recorded baseline [{status}]");
            }
            None => println!("  {name}: [{status}]"),
        }
        if status == "regressed" {
            regressed += 1;
        }
        if status == "no-baseline" {
            missing.push(name);
        }
    }
    if !missing.is_empty() {
        println!(
            "  {} row(s) lack a recorded baseline ({}) — refresh with \
             RATSIM_BENCH_OUT=BENCH_baseline.json cargo bench --bench sim_core",
            missing.len(),
            missing.join(", ")
        );
    }
    regressed
}
