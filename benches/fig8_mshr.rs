//! Regenerates Fig 8 (L1-MSHR hit-under-miss decomposition, 16 GPUs).
mod bench_common;
use ratsim::harness::{breakdown_sweep, fig8};

fn main() {
    bench_common::run_figure("fig8_mshr", |o| {
        let sweep = breakdown_sweep(o)?;
        fig8(o, &sweep)
    });
}
