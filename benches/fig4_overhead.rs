//! Regenerates Fig 4 (RAT overhead vs ideal) on quick axes.
mod bench_common;
use ratsim::harness::{fig4, main_sweep};

fn main() {
    bench_common::run_figure("fig4_overhead", |o| {
        let sweep = main_sweep(o)?;
        fig4(o, &sweep)
    });
}
