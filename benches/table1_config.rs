//! Regenerates Table 1 (simulation setup echo) — the config contract.
mod bench_common;
use ratsim::harness::table1;

fn main() {
    bench_common::run_figure("table1_config", table1);
}
