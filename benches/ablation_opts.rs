//! Regenerates the §6 optimization ablation (pre-translation + prefetch).
mod bench_common;
use ratsim::harness::ablation;

fn main() {
    bench_common::run_figure("ablation_opts", ablation);
}
