//! Regenerates Figs 9/10 (per-request RAT latency traces).
mod bench_common;
use ratsim::harness::fig9_10;

fn main() {
    bench_common::run_figure("fig9_10_traces", fig9_10);
}
