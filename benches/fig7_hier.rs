//! Regenerates Fig 7 (translation-module hit/miss stack, 16 GPUs).
mod bench_common;
use ratsim::harness::{breakdown_sweep, fig7};

fn main() {
    bench_common::run_figure("fig7_hier", |o| {
        let sweep = breakdown_sweep(o)?;
        fig7(o, &sweep)
    });
}
