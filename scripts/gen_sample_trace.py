#!/usr/bin/env python3
"""Regenerate examples/traces/sample_serving.csv.

Deterministic (fixed seed, no float-ordering hazards beyond the stdlib
Mersenne Twister, which is stable across CPython versions): a ~50 ms
serving burst on a 16-GPU pod — 128 jobs with Zipf popularity, groups of
4 or 8 contiguous ranks, log-normal collective sizes quantized to 4 KiB,
diurnal-modulated exponential inter-arrivals. The format is the ratsim
trace grammar (see WORKLOADS.md "Trace catalog"); `ratsim replay
--trace examples/traces/sample_serving.csv` streams it.

The first 128 rows round-robin every job once so the checked-in trace
always carries >= 100 distinct jobs regardless of the Zipf tail.
"""

import math
import random

SEED = 0x5E12_71CE
ROWS = 1200
JOBS = 128
GPUS = 16
ZIPF = 1.1
MEAN_GAP_US = 40.0
PERIOD_US = 12_500.0
AMP = 0.6
QUANTUM = 4096
OUT = "examples/traces/sample_serving.csv"

rng = random.Random(SEED)

# Zipf CDF over job ranks.
weights = [1.0 / (j + 1) ** ZIPF for j in range(JOBS)]
total_w = sum(weights)
cdf = []
acc = 0.0
for w in weights:
    acc += w / total_w
    cdf.append(acc)


def pick_job(i):
    if i < JOBS:
        return i  # round-robin warm-up: every job appears at least once
    u = rng.random()
    for j, c in enumerate(cdf):
        if u <= c:
            return j
    return JOBS - 1


def pick_size():
    # Log-normal around 32 KiB, quantized up to 4 KiB, clamped to 1 MiB.
    b = math.exp(rng.gauss(math.log(32 * 1024), 0.6))
    q = max(QUANTUM, math.ceil(b / QUANTUM) * QUANTUM)
    return min(q, 1 << 20)


def pick_group():
    g = 8 if rng.random() < 0.5 else 4
    start = rng.randrange(GPUS - g + 1)
    return f"{start}-{start + g - 1}", g


def pick_coll():
    u = rng.random()
    if u < 0.70:
        return "alltoall", "direct"
    if u < 0.85:
        return "allgather", "ring"
    return "allreduce", "ring"


rows = []
t_us = 0.0
for i in range(ROWS):
    # Diurnal-modulated exponential gap: rate 1 + AMP*sin(2*pi*t/period).
    rate = 1.0 + AMP * math.sin(2.0 * math.pi * t_us / PERIOD_US)
    t_us += rng.expovariate(1.0) * MEAN_GAP_US / max(rate, 1e-9)
    job = pick_job(i)
    coll, algo = pick_coll()
    size = pick_size()
    group, _ = pick_group()
    rows.append(f"{int(t_us)},job-{job:03d},{coll},{algo},{size},{group}")

with open(OUT, "w") as f:
    f.write("# sample serving trace — regenerate with scripts/gen_sample_trace.py\n")
    f.write(f"# {ROWS} rows, {JOBS} jobs, {GPUS}-GPU pod, ~{int(t_us/1000)} ms span\n")
    f.write("t_us,job,coll,algo,bytes,gpus\n")
    f.write("\n".join(rows) + "\n")

print(f"wrote {OUT}: {ROWS} rows, span {int(t_us)} us")
