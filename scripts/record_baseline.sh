#!/usr/bin/env bash
# Record the perf-trajectory baseline for benches/sim_core.rs.
#
# Runs the simulator-core bench suite and writes its BENCHJSON snapshot
# over BENCH_baseline.json — the numbers the CI bench-smoke job's
# RATSIM_BENCH_ENFORCE gate compares against. Commit the refreshed file
# to update the baseline (ROADMAP item: land actual perf numbers).
#
# Usage:
#   scripts/record_baseline.sh            # full iterations
#   scripts/record_baseline.sh --quick    # RATSIM_BENCH_QUICK=1, matches
#                                         # the CI smoke job's trimmed axes
#
# Prefer recording on the CI reference runner (the manually-dispatched
# .github/workflows/bench-baseline.yml does exactly this); a local
# recording is fine for relative comparisons on one machine.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${1:-}" = "--quick" ]; then
  export RATSIM_BENCH_QUICK=1
  shift
fi
if [ $# -gt 0 ]; then
  echo "usage: $0 [--quick]" >&2
  exit 2
fi

RATSIM_BENCH_OUT=BENCH_baseline.json cargo bench --bench sim_core

echo
echo "BENCH_baseline.json refreshed — review the numbers and commit it."
